//! watersic-lint: the repo's own static checks, run as
//! `cargo run -p xtask -- lint` (CI blocks on it).
//!
//! Ten rule families, tuned to this codebase's pinned invariants (see
//! `rust/xtask/README.md` for the full contract and the suppression
//! syntax):
//!
//! - `unsafe-safety` — every `unsafe` block, fn, or impl carries an
//!   adjacent `// SAFETY:` comment (or a `/// # Safety` doc section).
//! - `no-fma` — no fused-multiply-add tokens (`mul_add`, `fma`,
//!   `vfma`) anywhere in `rust/src/linalg/`: the kernels' bit-for-bit
//!   reproducibility contract requires separate mul + add rounding.
//! - `no-panic-untrusted` — no `.unwrap()` / `.expect(` / `panic!(`
//!   outside `#[cfg(test)]` in the untrusted-input surfaces
//!   (`runtime/server.rs`, `coordinator/container.rs`,
//!   `entropy/rans.rs`): malformed bytes must become `Err`, not a
//!   crashed serving thread.
//! - `no-partial-cmp-unwrap` — `partial_cmp(..).unwrap()` anywhere is
//!   a NaN landmine; `total_cmp` is the house idiom.
//! - `env-registry` — every `WATERSIC_*` engine option is read through
//!   `util::env` (no direct `env::var("WATERSIC_..")` elsewhere),
//!   every such string literal names a registered knob, every
//!   registered knob is documented in `main.rs` USAGE, and every knob
//!   the top-level `README.md` ops section mentions is registered (so
//!   the ops docs cannot drift from the code).
//! - `lint-allow` — suppression comments must name a known rule and
//!   carry an em-dash reason (exact syntax in the README).
//! - `no-raw-sync` — raw `std::sync` lock primitives (`Mutex`,
//!   `RwLock`, `Condvar`, their guards, `PoisonError`) are banned
//!   outside `util/sync.rs`: the tracked wrappers are the one place
//!   poisoning and lock-order discipline are handled.
//! - `lock-order` — acquisition nesting is extracted per function
//!   (with one level of follow-through into named helpers), the edges
//!   feed a global acquisition-order graph, and any cycle fails the
//!   lint.  Lock class keys are receiver chains (`pool.mx`, `queue`,
//!   `STATE`), so a given lock must be named consistently.
//! - `reactor-blocking` — blocking calls (`sleep`, `read_to_end`,
//!   `write_all`, blocking-mode flips, a lock guard live across the
//!   poll wait) are banned in `runtime/reactor.rs`: one stalled call
//!   there stalls every connection.
//! - `bench-json-sync` — CI's `grep`s over `BENCH_*.json` and the
//!   benches that write those files must agree: every grepped entry
//!   name matches an entry template the writing bench emits (a
//!   `{...}` format placeholder is a wildcard), every bench gating
//!   under `WATERSIC_BENCH_ENFORCE` declares its gated entries in a
//!   `GATED_ENTRIES` const, and every gated entry is both emitted and
//!   grepped — so a gate's telemetry can neither drop out of the JSON
//!   nor out of CI silently.
//!
//! The analysis is a line-oriented scan over a "code view" of each
//! file (string and comment interiors blanked, positions preserved) —
//! deliberately not a full parser, so it stays dependency-free and
//! fast, at the cost of requiring rustfmt-shaped input (which CI's
//! `cargo fmt --check` already guarantees).

use std::collections::{HashMap, HashSet, VecDeque};
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

const KNOWN_RULES: &[&str] = &[
    "unsafe-safety",
    "no-fma",
    "no-panic-untrusted",
    "no-partial-cmp-unwrap",
    "env-registry",
    "lint-allow",
    "no-raw-sync",
    "lock-order",
    "reactor-blocking",
    "bench-json-sync",
];

/// Files whose inputs arrive from outside the process (wire bytes,
/// container files) — the no-panic rule applies here.
const UNTRUSTED: &[&str] = &[
    "rust/src/runtime/reactor.rs",
    "rust/src/runtime/server.rs",
    "rust/src/coordinator/container.rs",
    "rust/src/entropy/rans.rs",
];

const SCAN_ROOTS: &[&str] = &["rust/src", "rust/tests", "benches", "rust/xtask/src"];

/// Directory names never descended into: vendored stand-in crates and
/// the lint's own deliberately-failing fixture snippets.
const SKIP_DIRS: &[&str] = &["vendor", "fixtures"];

/// Home of the tracked lock wrappers — the one file allowed to name
/// the raw `std::sync` primitives, and the one file whose own internal
/// `inner.lock()` plumbing the lock-order extractor must not index.
const SYNC_FILE: &str = "rust/src/util/sync.rs";

/// The event-loop surface the `reactor-blocking` rule polices.
const REACTOR_FILE: &str = "rust/src/runtime/reactor.rs";

/// Idents banned outside `SYNC_FILE` by `no-raw-sync`.  Atomics,
/// `Arc`, `OnceLock`, and `mpsc` stay legal everywhere — only the
/// poisoning lock primitives are centralized.
const RAW_SYNC_IDENTS: &[&[u8]] = &[
    b"Mutex",
    b"RwLock",
    b"Condvar",
    b"MutexGuard",
    b"RwLockReadGuard",
    b"RwLockWriteGuard",
    b"PoisonError",
];

const ENV_REGISTRY_FILE: &str = "rust/src/util/env.rs";
const USAGE_FILE: &str = "rust/src/main.rs";
const README_FILE: &str = "README.md";

/// The workflow whose `BENCH_*.json` greps the `bench-json-sync` rule
/// reconciles against the benches' emitted entries.
const CI_WORKFLOW_FILE: &str = ".github/workflows/ci.yml";

#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct Finding {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

/// Output format for findings (`--format`): the plain text default, a
/// GitHub workflow-command annotation per finding, or a JSON array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Format {
    Text,
    Github,
    Json,
}

/// One finding in the selected format.  Every format is one line per
/// finding — for JSON, `main` wraps the lines in `[`…`]` and inserts
/// the separating commas.
fn render_finding(f: &Finding, format: Format) -> String {
    match format {
        Format::Text => format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg),
        Format::Github => format!(
            "::error file={},line={},title=watersic-lint {}::{}",
            f.file,
            f.line,
            f.rule,
            gh_escape(&f.msg)
        ),
        Format::Json => format!(
            "  {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"msg\": \"{}\"}}",
            json_escape(&f.file),
            f.line,
            f.rule,
            json_escape(&f.msg)
        ),
    }
}

/// GitHub workflow-command message escaping (the documented set).
fn gh_escape(s: &str) -> String {
    s.replace('%', "%25").replace('\r', "%0D").replace('\n', "%0A")
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

const USAGE: &str = "usage: cargo run -p xtask -- lint [--root DIR] [--format text|github|json]";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut root = PathBuf::from(".");
    let mut cmd: Option<&str> = None;
    let mut format = Format::Text;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "lint" => cmd = Some("lint"),
            "--root" => {
                i += 1;
                match args.get(i) {
                    Some(d) => root = PathBuf::from(d),
                    None => {
                        eprintln!("xtask: --root needs a directory");
                        return ExitCode::from(2);
                    }
                }
            }
            "--format" => {
                i += 1;
                format = match args.get(i).map(String::as_str) {
                    Some("text") => Format::Text,
                    Some("github") => Format::Github,
                    Some("json") => Format::Json,
                    other => {
                        eprintln!("xtask: --format wants text|github|json, got {other:?}");
                        return ExitCode::from(2);
                    }
                };
            }
            other => {
                eprintln!("xtask: unknown argument `{other}`");
                eprintln!("{USAGE}");
                return ExitCode::from(2);
            }
        }
        i += 1;
    }
    if cmd != Some("lint") {
        eprintln!("{USAGE}");
        return ExitCode::from(2);
    }
    match run_lint(&root) {
        Ok((findings, nfiles)) => {
            if format == Format::Json {
                println!("[");
            }
            for (i, f) in findings.iter().enumerate() {
                let sep = if format == Format::Json && i + 1 < findings.len() {
                    ","
                } else {
                    ""
                };
                println!("{}{sep}", render_finding(f, format));
            }
            if format == Format::Json {
                println!("]");
            }
            if findings.is_empty() {
                eprintln!("xtask lint: clean ({nfiles} files)");
                ExitCode::SUCCESS
            } else {
                eprintln!("xtask lint: {} finding(s)", findings.len());
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("xtask lint: {e}");
            ExitCode::from(2)
        }
    }
}

/// Lint the whole tree under `root`; returns (findings, files seen).
fn run_lint(root: &Path) -> Result<(Vec<Finding>, usize), String> {
    let env_src = fs::read_to_string(root.join(ENV_REGISTRY_FILE))
        .map_err(|e| format!("reading {ENV_REGISTRY_FILE}: {e}"))?;
    let knobs = parse_knobs(&env_src);
    if knobs.is_empty() {
        return Err(format!("no knobs parsed from {ENV_REGISTRY_FILE}"));
    }
    let main_src = fs::read_to_string(root.join(USAGE_FILE))
        .map_err(|e| format!("reading {USAGE_FILE}: {e}"))?;

    let files = collect_files(root);
    let mut sources: Vec<(String, String)> = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(path).map_err(|e| format!("reading {rel}: {e}"))?;
        sources.push((rel, src));
    }
    let mut findings = Vec::new();
    for (rel, src) in &sources {
        findings.extend(lint_source(rel, src, &knobs));
    }
    findings.extend(lock_order_findings(&sources));
    // CI's bench-telemetry greps and the benches that emit the
    // entries must agree (absence of the workflow file — e.g. linting
    // an export — skips only the grep directions)
    let ci_src = fs::read_to_string(root.join(CI_WORKFLOW_FILE)).ok();
    findings.extend(bench_json_sync_findings(
        ci_src.as_deref().map(|s| (CI_WORKFLOW_FILE, s)),
        &sources,
    ));
    for name in &knobs {
        if !main_src.contains(name.as_str()) {
            findings.push(Finding {
                file: USAGE_FILE.to_string(),
                line: 1,
                rule: "env-registry",
                msg: format!("registered knob {name} is missing from the USAGE text"),
            });
        }
    }
    // the ops README may only name registered knobs — stale or
    // misspelled docs fail the lint instead of drifting silently
    if let Ok(readme) = fs::read_to_string(root.join(README_FILE)) {
        for (line, name) in doc_knob_mentions(&readme) {
            if !knobs.iter().any(|k| k == &name) {
                findings.push(Finding {
                    file: README_FILE.to_string(),
                    line,
                    rule: "env-registry",
                    msg: format!("{name} is not registered in util::env::KNOBS"),
                });
            }
        }
    }
    findings.sort();
    Ok((findings, files.len()))
}

fn collect_files(root: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    for r in SCAN_ROOTS {
        let d = root.join(r);
        if d.is_dir() {
            walk(&d, &mut out);
        }
    }
    out.sort();
    out
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = fs::read_dir(dir) else { return };
    for entry in rd.flatten() {
        let p = entry.path();
        if p.is_dir() {
            let name = p.file_name().and_then(|s| s.to_str()).unwrap_or("");
            if !SKIP_DIRS.contains(&name) {
                walk(&p, out);
            }
        } else if p.extension().and_then(|s| s.to_str()) == Some("rs") {
            out.push(p);
        }
    }
}

/// `WATERSIC_*` knob names mentioned in a prose document, with their
/// 1-based line numbers.  A bare `WATERSIC_` prefix (as in the phrase
/// "any `WATERSIC_*` knob") is not a mention.
fn doc_knob_mentions(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let mut rest = line;
        while let Some(p) = rest.find("WATERSIC_") {
            let tail = &rest[p..];
            let end = tail
                .find(|c: char| !(c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_'))
                .unwrap_or(tail.len());
            if end > "WATERSIC_".len() {
                out.push((i + 1, tail[..end].to_string()));
            }
            rest = &tail[end..];
        }
    }
    out
}

/// Knob names registered in `util::env::KNOBS` (`name: "..."` fields).
fn parse_knobs(env_src: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = env_src;
    while let Some(p) = rest.find("name: \"") {
        let after = &rest[p + 7..];
        if let Some(q) = after.find('"') {
            let name = &after[..q];
            if name.starts_with("WATERSIC_") {
                out.push(name.to_string());
            }
            rest = &after[q..];
        } else {
            break;
        }
    }
    out
}

/// The per-file rule families over one file (`lock-order` is the
/// cross-file pass in [`lock_order_findings`]).  `rel` is the
/// repo-relative path with `/` separators — it selects which
/// path-scoped rules apply, so tests can exercise fixtures as if they
/// lived anywhere.
fn lint_source(rel: &str, src: &str, knobs: &[String]) -> Vec<Finding> {
    let mut findings = Vec::new();
    let raw_lines: Vec<&str> = src.split('\n').collect();
    let (code, comments) = code_view(src);
    let line_starts = line_starts(src.as_bytes());
    let test_ranges = cfg_test_ranges(&code);
    let supp = Suppressions::parse(src, &comments, &line_starts, rel, &mut findings);

    let finding = |line: usize, rule: &'static str, msg: String| Finding {
        file: rel.to_string(),
        line,
        rule,
        msg,
    };

    let in_linalg = rel.starts_with("rust/src/linalg/");
    let untrusted = UNTRUSTED.contains(&rel);
    let in_sync = rel == SYNC_FILE;
    let in_reactor = rel == REACTOR_FILE;
    // fn declaration lines, so a reactor-blocking suppression above a
    // `fn` can cover the whole function (the threaded-fallback idiom)
    let fns = if in_reactor {
        fn_spans(&code, &line_starts)
    } else {
        Vec::new()
    };
    let fn_covered = |rule: &'static str, pos: usize, line: usize| {
        supp.covers(&raw_lines, rule, line)
            || fns
                .iter()
                .find(|f| f.body_start < pos && pos < f.body_end)
                .is_some_and(|f| supp.covers(&raw_lines, rule, f.decl_line))
    };

    for (start, end) in idents(&code) {
        let tok = &code[start..end];
        let line = line_at(&line_starts, start);

        // R1: unsafe-safety
        if tok == b"unsafe" {
            let here = raw_lines.get(line - 1).copied().unwrap_or("");
            let ok = here.contains("SAFETY:")
                || safety_context_above(&raw_lines, line)
                    .iter()
                    .any(|t| t.contains("SAFETY:") || t.contains("# Safety"));
            if !ok && !supp.covers(&raw_lines, "unsafe-safety", line) {
                findings.push(finding(
                    line,
                    "unsafe-safety",
                    "`unsafe` without an adjacent `// SAFETY:` comment or \
                     `/// # Safety` section"
                        .to_string(),
                ));
            }
        }

        // R2: no-fma (linalg only)
        if in_linalg {
            let lower: Vec<u8> = tok.iter().map(|c| c.to_ascii_lowercase()).collect();
            if subslice(tok, b"mul_add") || subslice(&lower, b"fma") {
                if !supp.covers(&raw_lines, "no-fma", line) {
                    findings.push(finding(
                        line,
                        "no-fma",
                        format!(
                            "fused-multiply-add token `{}` in linalg/ breaks the \
                             separate-rounding reproducibility contract",
                            String::from_utf8_lossy(tok)
                        ),
                    ));
                }
            }
        }

        // R3: no-panic-untrusted
        if untrusted && !in_ranges(&test_ranges, start) {
            let hit = match tok {
                b"unwrap" => {
                    prev_nonws(&code, start) == Some(b'.') && call_is_empty(&code, end)
                }
                b"expect" => {
                    prev_nonws(&code, start) == Some(b'.')
                        && next_nonws(&code, end) == Some(b'(')
                }
                b"panic" => {
                    next_nonws(&code, end) == Some(b'!')
                        // `panic!` then `(`: skip the `!` and any ws
                        && next_nonws(&code, skip_to(&code, end, b'!') + 1) == Some(b'(')
                }
                _ => false,
            };
            if hit && !supp.covers(&raw_lines, "no-panic-untrusted", line) {
                findings.push(finding(
                    line,
                    "no-panic-untrusted",
                    format!(
                        "`{}` on an untrusted-input surface — return Err or \
                         suppress with a reason",
                        String::from_utf8_lossy(tok)
                    ),
                ));
            }
        }

        // R6: no-raw-sync — the poisoning lock primitives live in
        // util/sync.rs only; everything else takes the tracked wrappers
        if !in_sync
            && RAW_SYNC_IDENTS.contains(&tok)
            && !supp.covers(&raw_lines, "no-raw-sync", line)
        {
            findings.push(finding(
                line,
                "no-raw-sync",
                format!(
                    "raw std::sync `{}` outside util/sync.rs — use the \
                     tracked wrappers (util::sync)",
                    String::from_utf8_lossy(tok)
                ),
            ));
        }

        // R7: reactor-blocking — one blocked call on the event loop
        // stalls every connection behind it
        if in_reactor && !in_ranges(&test_ranges, start) {
            let blocking = match tok {
                b"sleep" | b"read_until" | b"read_to_end" | b"read_exact" | b"write_all" => {
                    next_nonws(&code, end) == Some(b'(')
                }
                b"recv" | b"join" => {
                    prev_nonws(&code, start) == Some(b'.') && call_is_empty(&code, end)
                }
                b"set_nonblocking" => {
                    code.get(end) == Some(&b'(') && next_nonws(&code, end + 1) == Some(b'f')
                }
                _ => false,
            };
            if blocking && !fn_covered("reactor-blocking", start, line) {
                findings.push(finding(
                    line,
                    "reactor-blocking",
                    format!(
                        "blocking call `{}` on the reactor event loop — poll \
                         readiness instead, or suppress on a non-event-loop path",
                        String::from_utf8_lossy(tok)
                    ),
                ));
            }
        }

        // R4: no-partial-cmp-unwrap (everywhere)
        if tok == b"partial_cmp" {
            if let Some(after) = balanced_call_end(&code, end) {
                let mut tail = Vec::with_capacity(12);
                let mut j = after;
                while j < code.len() && tail.len() < 12 {
                    if !code[j].is_ascii_whitespace() {
                        tail.push(code[j]);
                    }
                    j += 1;
                }
                if tail.starts_with(b".unwrap()") || tail.starts_with(b".expect(") {
                    if !supp.covers(&raw_lines, "no-partial-cmp-unwrap", line) {
                        findings.push(finding(
                            line,
                            "no-partial-cmp-unwrap",
                            "`partial_cmp(..).unwrap()` panics on NaN — use \
                             `total_cmp`"
                                .to_string(),
                        ));
                    }
                }
            }
        }
    }

    // R7b: a lock guard live across the poll wait serializes the whole
    // event loop on whatever that lock protects
    if in_reactor {
        let braces = brace_pairs(&code);
        for acq in direct_acquisitions(&code, &test_ranges) {
            let live_end = acq_live_end(&code, &braces, &acq);
            for (s, e) in idents(&code) {
                if &code[s..e] == b"wait"
                    && code.get(e) == Some(&b'(')
                    && s > acq.pos
                    && s < live_end
                {
                    let line = line_at(&line_starts, s);
                    if !fn_covered("reactor-blocking", s, line) {
                        findings.push(finding(
                            line,
                            "reactor-blocking",
                            format!(
                                "poll wait while the `{}` lock guard is live — \
                                 drop the guard before blocking",
                                acq.class
                            ),
                        ));
                    }
                }
            }
        }
    }

    // R5a: direct env reads of engine options outside the registry
    if rel != ENV_REGISTRY_FILE {
        let bytes = src.as_bytes();
        for pos in find_all(&code, b"env::var") {
            // the literal itself lives in the raw bytes (the code view
            // blanks string interiors but preserves every position)
            let mut j = pos + 8;
            if bytes.get(j..j + 3) == Some(&b"_os"[..]) {
                j += 3;
            }
            while j < bytes.len() && bytes[j].is_ascii_whitespace() {
                j += 1;
            }
            if bytes.get(j) != Some(&b'(') {
                continue;
            }
            j += 1;
            while j < bytes.len() && bytes[j].is_ascii_whitespace() {
                j += 1;
            }
            if bytes.get(j) == Some(&b'"') && bytes[j + 1..].starts_with(b"WATERSIC_") {
                let line = line_at(&line_starts, pos);
                if !supp.covers(&raw_lines, "env-registry", line) {
                    findings.push(finding(
                        line,
                        "env-registry",
                        "direct env read of a WATERSIC_* option — go through \
                         util::env"
                            .to_string(),
                    ));
                }
            }
        }
        // R5b: every quoted WATERSIC_* literal must be a registered knob
        for (pos, name) in watersic_literals(src) {
            if !knobs.iter().any(|k| k == &name) {
                let line = line_at(&line_starts, pos);
                if !supp.covers(&raw_lines, "env-registry", line) {
                    findings.push(finding(
                        line,
                        "env-registry",
                        format!("{name} is not registered in util::env::KNOBS"),
                    ));
                }
            }
        }
    }

    findings
}

// ---- suppressions -------------------------------------------------

struct Suppressions {
    by_line: HashMap<usize, Vec<&'static str>>,
}

impl Suppressions {
    /// Parse suppression comments — the marker, a known rule name in
    /// parens, then an em-dash (or `--`) and a reason; malformed ones
    /// become `lint-allow` findings.  Only true comment spans are
    /// scanned, so the marker inside a string literal is inert.
    fn parse(
        src: &str,
        comments: &[(usize, usize)],
        starts: &[usize],
        rel: &str,
        findings: &mut Vec<Finding>,
    ) -> Suppressions {
        let mut by_line: HashMap<usize, Vec<&'static str>> = HashMap::new();
        for &(cs, ce) in comments {
            let c = &src[cs..ce];
            let Some(q) = c.find("lint:allow(") else { continue };
            let ln = line_at(starts, cs + q);
            let after = &c[q + "lint:allow(".len()..];
            let Some(r) = after.find(')') else {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: ln,
                    rule: "lint-allow",
                    msg: "unclosed lint:allow(".to_string(),
                });
                continue;
            };
            let rule = after[..r].trim();
            let Some(&known) = KNOWN_RULES.iter().find(|k| **k == rule) else {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: ln,
                    rule: "lint-allow",
                    msg: format!("unknown rule `{rule}` in lint:allow"),
                });
                continue;
            };
            let rest = after[r + 1..].trim_start();
            let reason = rest
                .strip_prefix('—')
                .or_else(|| rest.strip_prefix("--"))
                .or_else(|| rest.strip_prefix('-'))
                .map(str::trim)
                .unwrap_or("");
            if reason.is_empty() {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: ln,
                    rule: "lint-allow",
                    msg: format!(
                        "suppression needs a reason: `// lint:allow({rule}) — why`"
                    ),
                });
                continue;
            }
            by_line.entry(ln).or_default().push(known);
        }
        Suppressions { by_line }
    }

    /// A violation on `line` is covered by an allow on that line or in
    /// the contiguous comment block immediately above it.
    fn covers(&self, raw_lines: &[&str], rule: &'static str, line: usize) -> bool {
        let at = |ln: usize| self.by_line.get(&ln).is_some_and(|v| v.contains(&rule));
        if at(line) {
            return true;
        }
        let mut i = line - 1;
        while i >= 1 {
            let t = raw_lines.get(i - 1).map(|s| s.trim()).unwrap_or("");
            if t.starts_with("//") {
                if at(i) {
                    return true;
                }
                i -= 1;
            } else {
                break;
            }
        }
        false
    }
}

/// Lines to search for a SAFETY comment above `line`: contiguous
/// comments, attribute lines, and statement continuations (a previous
/// line that doesn't end in `;`/`{`/`}` means `line` belongs to the
/// same statement, so keep walking up to the statement's own comment).
fn safety_context_above<'a>(raw_lines: &[&'a str], line: usize) -> Vec<&'a str> {
    let mut texts = Vec::new();
    let mut i = line - 1;
    while i >= 1 {
        let t = raw_lines.get(i - 1).map(|s| s.trim()).unwrap_or("");
        if t.starts_with("//") {
            texts.push(t);
            i -= 1;
        } else if t.starts_with("#[") || t.starts_with("#![") {
            i -= 1;
        } else if !t.is_empty() && !t.ends_with([';', '{', '}']) {
            i -= 1;
        } else {
            break;
        }
    }
    texts
}

// ---- code view ----------------------------------------------------

/// Copy of the source with comment bodies and string/char interiors
/// blanked to spaces (newlines kept), so token scans can't match text,
/// plus the byte spans of the comments themselves — suppressions are
/// parsed from those spans only, so the marker appearing inside a
/// string literal is inert.
fn code_view(src: &str) -> (Vec<u8>, Vec<(usize, usize)>) {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let mut comments = Vec::new();
    let mut i = 0;
    while i < n {
        match b[i] {
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                let mut j = i;
                while j < n && b[j] != b'\n' {
                    j += 1;
                }
                blank(&mut out, i, j);
                comments.push((i, j));
                i = j;
            }
            b'/' if i + 1 < n && b[i + 1] == b'*' => {
                let mut depth = 1usize;
                let mut j = i + 2;
                while j < n && depth > 0 {
                    if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                        depth += 1;
                        j += 2;
                    } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                blank(&mut out, i, j);
                comments.push((i, j));
                i = j;
            }
            b'r' if !ident_before(b, i) && raw_string_start(b, i).is_some() => {
                i = blank_raw_string(b, &mut out, i);
            }
            b'b' if !ident_before(b, i) && i + 1 < n && b[i + 1] == b'"' => {
                i = blank_plain_string(b, &mut out, i + 1);
            }
            b'b' if !ident_before(b, i)
                && i + 1 < n
                && b[i + 1] == b'r'
                && raw_string_start(b, i + 1).is_some() =>
            {
                i = blank_raw_string(b, &mut out, i + 1);
            }
            b'"' => {
                i = blank_plain_string(b, &mut out, i);
            }
            b'\'' => {
                i = blank_char_or_lifetime(b, &mut out, i);
            }
            _ => i += 1,
        }
    }
    (out, comments)
}

fn blank(out: &mut [u8], from: usize, to: usize) {
    for c in out[from.min(out.len())..to.min(out.len())].iter_mut() {
        if *c != b'\n' {
            *c = b' ';
        }
    }
}

fn ident_before(b: &[u8], i: usize) -> bool {
    i > 0 && (b[i - 1] == b'_' || b[i - 1].is_ascii_alphanumeric())
}

/// `Some(hash_count)` if `b[i..]` opens a raw string `r#*"`.
fn raw_string_start(b: &[u8], i: usize) -> Option<usize> {
    if b.get(i) != Some(&b'r') {
        return None;
    }
    let mut j = i + 1;
    while b.get(j) == Some(&b'#') {
        j += 1;
    }
    (b.get(j) == Some(&b'"')).then_some(j - i - 1)
}

/// Blank `"..."` starting at the quote `at`; returns the index after.
fn blank_plain_string(b: &[u8], out: &mut [u8], at: usize) -> usize {
    let n = b.len();
    let mut j = at + 1;
    while j < n {
        match b[j] {
            b'\\' => j += 2,
            b'"' => break,
            _ => j += 1,
        }
    }
    blank(out, at + 1, j.min(n));
    (j + 1).min(n)
}

/// Blank `r#"..."#` whose `r` is at `at`; returns the index after.
fn blank_raw_string(b: &[u8], out: &mut [u8], at: usize) -> usize {
    let n = b.len();
    let hashes = raw_string_start(b, at).unwrap_or(0);
    let body = at + 1 + hashes + 1;
    let mut j = body;
    while j < n {
        if b[j] == b'"' && b[j + 1..].iter().take(hashes).filter(|&&c| c == b'#').count() == hashes
        {
            blank(out, body, j);
            return j + 1 + hashes;
        }
        j += 1;
    }
    blank(out, body, n);
    n
}

/// Blank a char literal at `at`, or step over a lifetime tick.
fn blank_char_or_lifetime(b: &[u8], out: &mut [u8], at: usize) -> usize {
    let n = b.len();
    if at + 1 >= n {
        return at + 1;
    }
    if b[at + 1] == b'\\' {
        // escaped char literal: blank to the closing quote
        let mut j = at + 2;
        while j < n && b[j] != b'\'' {
            j += 1;
        }
        blank(out, at + 1, j.min(n));
        return (j + 1).min(n);
    }
    // single-char literal `'x'` (possibly multi-byte UTF-8); anything
    // else — `'a` in generics, `&'static` — is a lifetime: skip it
    let ch_len = utf8_len(b[at + 1]);
    if at + 1 + ch_len < n && b[at + 1 + ch_len] == b'\'' && b[at + 1] != b'\'' {
        blank(out, at + 1, at + 1 + ch_len);
        at + 2 + ch_len
    } else {
        at + 1
    }
}

fn utf8_len(lead: u8) -> usize {
    match lead {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

// ---- scanning helpers ---------------------------------------------

/// Byte offsets where each line starts (index 0 = line 1).
fn line_starts(b: &[u8]) -> Vec<usize> {
    let mut v = vec![0usize];
    for (i, &c) in b.iter().enumerate() {
        if c == b'\n' {
            v.push(i + 1);
        }
    }
    v
}

fn line_at(starts: &[usize], pos: usize) -> usize {
    starts.partition_point(|&s| s <= pos)
}

/// `(start, end)` of every identifier token in the code view.
fn idents(code: &[u8]) -> Vec<(usize, usize)> {
    let mut v = Vec::new();
    let mut i = 0;
    let n = code.len();
    while i < n {
        let c = code[i];
        if c == b'_' || c.is_ascii_alphabetic() {
            let s = i;
            while i < n && (code[i] == b'_' || code[i].is_ascii_alphanumeric()) {
                i += 1;
            }
            v.push((s, i));
        } else if c.is_ascii_digit() {
            // numeric literal (incl. a suffix like `0usize`): not an
            // ident — but stop at `.` so `x.0.unwrap()` still yields
            // the `unwrap` token
            while i < n && (code[i] == b'_' || code[i].is_ascii_alphanumeric()) {
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    v
}

fn subslice(hay: &[u8], needle: &[u8]) -> bool {
    hay.windows(needle.len()).any(|w| w == needle)
}

fn find_all(hay: &[u8], needle: &[u8]) -> Vec<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return Vec::new();
    }
    (0..=hay.len() - needle.len())
        .filter(|&i| &hay[i..i + needle.len()] == needle)
        .collect()
}

fn prev_nonws(code: &[u8], mut i: usize) -> Option<u8> {
    while i > 0 {
        i -= 1;
        if !code[i].is_ascii_whitespace() {
            return Some(code[i]);
        }
    }
    None
}

fn next_nonws(code: &[u8], mut i: usize) -> Option<u8> {
    while i < code.len() {
        if !code[i].is_ascii_whitespace() {
            return Some(code[i]);
        }
        i += 1;
    }
    None
}

/// First index at or after `i` holding `what` (or `code.len()`).
fn skip_to(code: &[u8], mut i: usize, what: u8) -> usize {
    while i < code.len() && code[i] != what {
        i += 1;
    }
    i
}

/// `.unwrap()` check: after the ident, `(` then `)` with only ws.
fn call_is_empty(code: &[u8], end: usize) -> bool {
    let open = skip_to(code, end, b'(');
    if next_nonws(code, end) != Some(b'(') {
        return false;
    }
    next_nonws(code, open + 1) == Some(b')')
}

/// Index just past the balanced `(...)` that follows `end`, if any.
fn balanced_call_end(code: &[u8], end: usize) -> Option<usize> {
    if next_nonws(code, end) != Some(b'(') {
        return None;
    }
    let open = skip_to(code, end, b'(');
    let mut depth = 1usize;
    let mut j = open + 1;
    while j < code.len() && depth > 0 {
        match code[j] {
            b'(' => depth += 1,
            b')' => depth -= 1,
            _ => {}
        }
        j += 1;
    }
    (depth == 0).then_some(j)
}

/// Byte ranges of `#[cfg(test)]` / `#[cfg(all(test, ...))]` items
/// (attribute through closing brace) in the code view.
fn cfg_test_ranges(code: &[u8]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    for marker in [&b"#[cfg(test)]"[..], &b"#[cfg(all(test"[..]] {
        for m in find_all(code, marker) {
            let mut k = m + marker.len();
            // opening brace of the following item (a `;` first means the
            // attribute decorated a brace-less item: nothing to span)
            let mut open = None;
            while k < code.len() {
                match code[k] {
                    b'{' => {
                        open = Some(k);
                        break;
                    }
                    b';' => break,
                    _ => k += 1,
                }
            }
            let Some(open) = open else { continue };
            let mut depth = 1usize;
            let mut j = open + 1;
            while j < code.len() && depth > 0 {
                match code[j] {
                    b'{' => depth += 1,
                    b'}' => depth -= 1,
                    _ => {}
                }
                j += 1;
            }
            ranges.push((m, j));
        }
    }
    ranges
}

fn in_ranges(ranges: &[(usize, usize)], pos: usize) -> bool {
    ranges.iter().any(|&(a, b)| pos >= a && pos < b)
}

/// `(offset, name)` of every quoted `"WATERSIC_..."` literal.
fn watersic_literals(src: &str) -> Vec<(usize, String)> {
    let b = src.as_bytes();
    let mut out = Vec::new();
    for pos in find_all(b, b"\"WATERSIC_") {
        let start = pos + 1;
        let mut j = start;
        while j < b.len() && (b[j] == b'_' || b[j].is_ascii_uppercase() || b[j].is_ascii_digit())
        {
            j += 1;
        }
        // require a non-empty suffix and the closing quote so prefix
        // constants like `"WATERSIC_"` don't register as knob names
        if j > start + "WATERSIC_".len() && b.get(j) == Some(&b'"') {
            out.push((pos, String::from_utf8_lossy(&b[start..j]).to_string()));
        }
    }
    out
}

// ---- bench-json-sync ----------------------------------------------

/// One bench binary's JSON telemetry surface: which `BENCH_*.json` it
/// writes, the entry-name templates it emits, and the entries its
/// `WATERSIC_BENCH_ENFORCE` gates declare via a `GATED_ENTRIES`
/// const.
struct BenchSurface {
    file: String,
    json: String,
    templates: Vec<String>,
    /// `(line, entry)` per declared gated entry.
    gated: Vec<(usize, String)>,
    /// Line of the first `WATERSIC_BENCH_ENFORCE` mention, if any.
    enforce_line: Option<usize>,
    has_gated_const: bool,
}

/// First plain `"..."` literal within `window` bytes after `from` in
/// the raw source (raw-string and escape-heavy literals don't occur in
/// the bench-entry surface this serves).
fn literal_after(src: &str, from: usize, window: usize) -> Option<String> {
    let b = src.as_bytes();
    let end = (from + window).min(b.len());
    let mut i = from;
    while i < end && b[i] != b'"' {
        i += 1;
    }
    if i >= end {
        return None;
    }
    let start = i + 1;
    let mut j = start;
    while j < b.len() && b[j] != b'"' {
        if b[j] == b'\\' {
            j += 1;
        }
        j += 1;
    }
    if j >= b.len() {
        return None;
    }
    Some(src[start..j].to_string())
}

/// Parse one bench source's surface (`None` when the file never
/// constructs a `BenchLog`).  Entry templates come from the literal
/// (or `format!` template) heading every `.note(` / `.meta(` /
/// `Bench::new(` call — `Bench::new` names flow into the JSON via
/// `log.record`.
fn bench_surface(rel: &str, src: &str) -> Option<BenchSurface> {
    let b = src.as_bytes();
    let starts = line_starts(b);
    let new_pos = find_all(b, b"BenchLog::new(").first().copied()?;
    let json = literal_after(src, new_pos, 200)?;
    let mut templates = Vec::new();
    for marker in [
        b".note(".as_slice(),
        b".meta(".as_slice(),
        b"Bench::new(".as_slice(),
    ] {
        for pos in find_all(b, marker) {
            if let Some(lit) = literal_after(src, pos + marker.len(), 200) {
                templates.push(lit);
            }
        }
    }
    let mut gated = Vec::new();
    let mut has_gated_const = false;
    if let Some(pos) = find_all(b, b"const GATED_ENTRIES").first().copied() {
        has_gated_const = true;
        // entries are the string literals between the initializer's
        // `[` (found after `=`, past the `&[&str]` type) and its `]`
        let open = skip_to(b, skip_to(b, pos, b'='), b'[');
        let mut i = open + 1;
        while i < b.len() && b[i] != b']' {
            if b[i] == b'"' {
                if let Some(lit) = literal_after(src, i, 200) {
                    gated.push((line_at(&starts, i), lit.clone()));
                    i += lit.len() + 2;
                    continue;
                }
            }
            i += 1;
        }
    }
    let enforce_line = find_all(b, b"WATERSIC_BENCH_ENFORCE")
        .first()
        .map(|&p| line_at(&starts, p));
    Some(BenchSurface {
        file: rel.to_string(),
        json,
        templates,
        gated,
        enforce_line,
        has_gated_const,
    })
}

/// `(line, entry, json)` for every `grep … '"ENTRY"' … BENCH_*.json`
/// line in a workflow file.  Greps against other files (logs, stdout
/// captures) carry no `BENCH_*.json` token and are ignored.
fn ci_bench_greps(ci: &str) -> Vec<(usize, String, String)> {
    let mut out = Vec::new();
    for (i, line) in ci.lines().enumerate() {
        if !line.contains("grep") {
            continue;
        }
        let Some(j) = line.find("BENCH_") else { continue };
        let Some(k) = line[j..].find(".json") else { continue };
        let json = line[j..j + k + ".json".len()].to_string();
        let Some(a) = line.find("'\"") else { continue };
        let rest = &line[a + 2..];
        let Some(close) = rest.find("\"'") else { continue };
        out.push((i + 1, rest[..close].to_string(), json));
    }
    out
}

/// Does an emitted entry-name template match a concrete entry name?
/// `{...}` spans (`format!` placeholders) are wildcards; the literal
/// segments must match in order, anchored at both ends.
fn template_matches(template: &str, name: &str) -> bool {
    let mut segs: Vec<&str> = Vec::new();
    let mut rest = template;
    loop {
        let Some(i) = rest.find('{') else {
            segs.push(rest);
            break;
        };
        let Some(j) = rest[i..].find('}') else {
            return false; // unbalanced `{` — not a format template
        };
        segs.push(&rest[..i]);
        rest = &rest[i + j + 1..];
    }
    if segs.len() == 1 {
        return template == name;
    }
    let first = segs[0];
    let last = segs[segs.len() - 1];
    if !name.starts_with(first)
        || !name.ends_with(last)
        || name.len() < first.len() + last.len()
    {
        return false;
    }
    let mut pos = first.len();
    let cap = name.len() - last.len();
    for seg in &segs[1..segs.len() - 1] {
        if seg.is_empty() {
            continue;
        }
        match name[pos..cap].find(seg) {
            Some(k) => pos += k + seg.len(),
            None => return false,
        }
    }
    true
}

/// The `bench-json-sync` cross-file pass: every `BENCH_*.json` entry
/// CI greps must be emitted by the bench that writes that file, every
/// gating bench declares `GATED_ENTRIES`, and every gated entry is
/// both emitted and grepped.  `ci` is the workflow file as
/// `(path, text)`; `None` skips the grep directions only.
fn bench_json_sync_findings(
    ci: Option<(&str, &str)>,
    sources: &[(String, String)],
) -> Vec<Finding> {
    const RULE: &str = "bench-json-sync";
    let surfaces: Vec<BenchSurface> = sources
        .iter()
        .filter(|(rel, _)| rel.starts_with("benches/"))
        .filter_map(|(rel, src)| bench_surface(rel, src))
        .collect();
    let ci_greps: Option<(&str, Vec<(usize, String, String)>)> =
        ci.map(|(path, text)| (path, ci_bench_greps(text)));
    let mut findings = Vec::new();
    for s in &surfaces {
        if let Some(line) = s.enforce_line {
            if !s.has_gated_const {
                findings.push(Finding {
                    file: s.file.clone(),
                    line,
                    rule: RULE,
                    msg: "gates under WATERSIC_BENCH_ENFORCE without declaring \
                          GATED_ENTRIES — the gated telemetry cannot be pinned"
                        .to_string(),
                });
            }
        }
        for (line, entry) in &s.gated {
            if !s.templates.iter().any(|t| template_matches(t, entry)) {
                findings.push(Finding {
                    file: s.file.clone(),
                    line: *line,
                    rule: RULE,
                    msg: format!(
                        "gated entry \"{entry}\" is never emitted into {} by this bench",
                        s.json
                    ),
                });
                continue;
            }
            if let Some((ci_path, greps)) = &ci_greps {
                if !greps
                    .iter()
                    .any(|(_, name, json)| json == &s.json && name == entry)
                {
                    findings.push(Finding {
                        file: s.file.clone(),
                        line: *line,
                        rule: RULE,
                        msg: format!(
                            "gated entry \"{entry}\" is not pinned by a grep of {} in {ci_path}",
                            s.json
                        ),
                    });
                }
            }
        }
    }
    if let Some((ci_path, greps)) = &ci_greps {
        for (line, name, json) in greps {
            match surfaces.iter().find(|s| &s.json == json) {
                None => findings.push(Finding {
                    file: ci_path.to_string(),
                    line: *line,
                    rule: RULE,
                    msg: format!("greps {json}, which no bench under benches/ writes"),
                }),
                Some(s) => {
                    if !s.templates.iter().any(|t| template_matches(t, name)) {
                        findings.push(Finding {
                            file: ci_path.to_string(),
                            line: *line,
                            rule: RULE,
                            msg: format!(
                                "grepped entry \"{name}\" is never emitted into {json} by {}",
                                s.file
                            ),
                        });
                    }
                }
            }
        }
    }
    findings
}

// ---- lock-order extraction ----------------------------------------

/// One `fn` item in the code view: its name, declaration line, and the
/// byte span of its brace body.
struct FnSpan {
    name: String,
    decl_line: usize,
    sig_start: usize,
    body_start: usize,
    body_end: usize,
}

/// Every `fn` item with a brace body.  The `fn` keyword must be
/// directly followed by the name, which filters `fn(..)` pointer types;
/// bodiless trait-method declarations are skipped.
fn fn_spans(code: &[u8], starts: &[usize]) -> Vec<FnSpan> {
    let mut out = Vec::new();
    let toks = idents(code);
    for (i, &(s, e)) in toks.iter().enumerate() {
        if &code[s..e] != b"fn" {
            continue;
        }
        let Some(&(ns, ne)) = toks.get(i + 1) else {
            continue;
        };
        if code[e..ns].iter().any(|c| !c.is_ascii_whitespace()) {
            continue;
        }
        let mut j = ne;
        let mut open = None;
        while j < code.len() {
            match code[j] {
                b'{' => {
                    open = Some(j);
                    break;
                }
                b';' => break,
                _ => j += 1,
            }
        }
        let Some(open) = open else { continue };
        out.push(FnSpan {
            name: String::from_utf8_lossy(&code[ns..ne]).to_string(),
            decl_line: line_at(starts, s),
            sig_start: s,
            body_start: open,
            body_end: match_brace(code, open),
        });
    }
    out
}

/// Position of the `}` matching the `{` at `open` (or `code.len()`).
fn match_brace(code: &[u8], open: usize) -> usize {
    let mut depth = 0usize;
    let mut j = open;
    while j < code.len() {
        match code[j] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
        j += 1;
    }
    code.len()
}

/// All `{`..`}` pairs in the code view, via a match stack.
fn brace_pairs(code: &[u8]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut stack = Vec::new();
    for (j, &c) in code.iter().enumerate() {
        match c {
            b'{' => stack.push(j),
            b'}' => {
                if let Some(o) = stack.pop() {
                    out.push((o, j));
                }
            }
            _ => {}
        }
    }
    out
}

/// A direct `.lock()` / `.read()` / `.write()` acquisition site, with
/// its receiver-chain class key and statement shape.
struct Acq {
    pos: usize,
    class: String,
    let_bound: bool,
}

/// Direct acquisition sites outside `#[cfg(test)]` items.  The class
/// key is the receiver chain minus a leading `self` (`self.queue.lock()`
/// and a helper's `queue.lock()` both key as `queue`), so naming a
/// given lock consistently across call-sites is part of the contract.
fn direct_acquisitions(code: &[u8], test_ranges: &[(usize, usize)]) -> Vec<Acq> {
    let mut out = Vec::new();
    for (s, e) in idents(code) {
        let tok = &code[s..e];
        if tok != b"lock" && tok != b"read" && tok != b"write" {
            continue;
        }
        if in_ranges(test_ranges, s)
            || prev_nonws(code, s) != Some(b'.')
            || !call_is_empty(code, e)
        {
            continue;
        }
        let Some(class) = receiver_chain(code, s) else {
            continue;
        };
        out.push(Acq {
            pos: s,
            class,
            let_bound: stmt_is_let(code, stmt_start(code, s)),
        });
    }
    out
}

/// Receiver segments of a method call at `ident_start`, walking back
/// over a plain `ident.ident.` chain (`self.queue.lock` ->
/// `["self", "queue"]`).  `None` when the receiver is not a plain
/// chain — e.g. a call result (`foo().lock()`) or an index expression.
fn receiver_segments(code: &[u8], ident_start: usize) -> Option<Vec<String>> {
    let mut segs = Vec::new();
    let mut j = ident_start;
    loop {
        // back over whitespace to the `.`
        while j > 0 && code[j - 1].is_ascii_whitespace() {
            j -= 1;
        }
        if j == 0 || code[j - 1] != b'.' {
            break;
        }
        j -= 1;
        while j > 0 && code[j - 1].is_ascii_whitespace() {
            j -= 1;
        }
        let end = j;
        while j > 0 && (code[j - 1] == b'_' || code[j - 1].is_ascii_alphanumeric()) {
            j -= 1;
        }
        if j == end {
            return None;
        }
        segs.push(String::from_utf8_lossy(&code[j..end]).to_string());
    }
    segs.reverse();
    if segs.is_empty() {
        None
    } else {
        Some(segs)
    }
}

/// Class key for an acquisition: the receiver chain joined with `.`,
/// minus a leading `self`.
fn receiver_chain(code: &[u8], ident_start: usize) -> Option<String> {
    let mut segs = receiver_segments(code, ident_start)?;
    if segs.first().map(String::as_str) == Some("self") {
        segs.remove(0);
    }
    if segs.is_empty() {
        None
    } else {
        Some(segs.join("."))
    }
}

/// Start of the statement containing `pos`: just after the previous
/// `;`, `{`, or `}`, skipping whitespace.
fn stmt_start(code: &[u8], pos: usize) -> usize {
    let mut j = pos;
    while j > 0 && !matches!(code[j - 1], b';' | b'{' | b'}') {
        j -= 1;
    }
    while j < code.len() && code[j].is_ascii_whitespace() {
        j += 1;
    }
    j
}

/// `true` when the statement at `start` is a `let` binding.
fn stmt_is_let(code: &[u8], start: usize) -> bool {
    code[start..].starts_with(b"let")
        && !code
            .get(start + 3)
            .is_some_and(|&c| c == b'_' || c.is_ascii_alphanumeric())
}

/// End of the innermost brace block containing `pos`.
fn enclosing_block_end(code: &[u8], braces: &[(usize, usize)], pos: usize) -> usize {
    braces
        .iter()
        .filter(|&&(o, c)| o < pos && pos < c)
        .map(|&(_, c)| c)
        .min()
        .unwrap_or(code.len())
}

/// Last position at which the guard from `acq` is held: a let-bound
/// guard lives to its enclosing block's close, a temporary to its
/// statement's `;`.
fn acq_live_end(code: &[u8], braces: &[(usize, usize)], acq: &Acq) -> usize {
    let block_end = enclosing_block_end(code, braces, acq.pos);
    if acq.let_bound {
        block_end
    } else {
        skip_to(code, acq.pos, b';').min(block_end)
    }
}

/// Whether a call at `start` participates in the one level of
/// inter-procedural follow-through.  Free and path calls always do;
/// method calls only as `self.helper()` or `ident.helper()` — deeper
/// receivers (`j.next.load()`) share names with std methods too freely
/// to index by bare name.
fn followable_call(code: &[u8], start: usize) -> bool {
    if prev_nonws(code, start) != Some(b'.') {
        return true;
    }
    matches!(receiver_segments(code, start), Some(s) if s.len() == 1)
}

/// Per-function lock facts, merged across files by bare name — the one
/// level of inter-procedural follow-through.  Name collisions merge
/// conservatively (union of classes), which can only add edges a human
/// reviewer would also have to consider.
#[derive(Default)]
struct FnLocks {
    classes: Vec<String>,
    returns_guard: bool,
}

/// The cross-file `lock-order` pass: record which lock classes are
/// acquired while which are held (guard liveness approximated as
/// let-binding -> enclosing block, temporary -> statement), follow one
/// level into named helpers, and flag every edge that closes a cycle in
/// the global acquisition graph.  `util/sync.rs` (the wrappers' own
/// plumbing) and `#[cfg(test)]` items are exempt; suppressions attach
/// to the inner-acquisition line or the enclosing `fn` line.
fn lock_order_findings(sources: &[(String, String)]) -> Vec<Finding> {
    struct Art {
        rel: String,
        src: String,
        code: Vec<u8>,
        starts: Vec<usize>,
        test_ranges: Vec<(usize, usize)>,
        fns: Vec<FnSpan>,
        acqs: Vec<Acq>,
        braces: Vec<(usize, usize)>,
        supp: Suppressions,
    }

    // pass 1: per-fn direct classes and guard-returning signatures
    let mut arts: Vec<Art> = Vec::new();
    let mut index: HashMap<String, FnLocks> = HashMap::new();
    for (rel, src) in sources {
        if rel == SYNC_FILE {
            continue;
        }
        let (code, comments) = code_view(src);
        let starts = line_starts(src.as_bytes());
        let test_ranges = cfg_test_ranges(&code);
        let fns = fn_spans(&code, &starts);
        let acqs = direct_acquisitions(&code, &test_ranges);
        let braces = brace_pairs(&code);
        let supp = Suppressions::parse(src, &comments, &starts, rel, &mut Vec::new());
        for f in &fns {
            if in_ranges(&test_ranges, f.sig_start) {
                continue;
            }
            let entry = index.entry(f.name.clone()).or_default();
            if subslice(&code[f.sig_start..f.body_start], b"Guard") {
                entry.returns_guard = true;
            }
            for a in &acqs {
                if a.pos <= f.body_start || a.pos >= f.body_end {
                    continue;
                }
                if !entry.classes.contains(&a.class) {
                    entry.classes.push(a.class.clone());
                }
            }
        }
        arts.push(Art {
            rel: rel.clone(),
            src: src.clone(),
            code,
            starts,
            test_ranges,
            fns,
            acqs,
            braces,
            supp,
        });
    }

    // pass 2: per-fn holdings x later acquisition events -> global edges
    struct Site {
        line: usize,
        fn_decl_line: usize,
        art: usize,
    }
    let mut adj: HashMap<String, Vec<String>> = HashMap::new();
    let mut pairs: Vec<(String, String, Site)> = Vec::new();
    let mut seen: HashSet<(String, String)> = HashSet::new();
    for (ai, art) in arts.iter().enumerate() {
        let toks = idents(&art.code);
        for f in &art.fns {
            if in_ranges(&art.test_ranges, f.sig_start) {
                continue;
            }
            // holdings: this fn's live guards; events: every acquisition
            // (direct, or one call deep through an indexed helper)
            let mut holdings: Vec<(usize, usize, String)> = Vec::new();
            let mut events: Vec<(usize, Vec<String>)> = Vec::new();
            for a in &art.acqs {
                if a.pos > f.body_start && a.pos < f.body_end {
                    let live = acq_live_end(&art.code, &art.braces, a);
                    holdings.push((a.pos, live, a.class.clone()));
                    events.push((a.pos, vec![a.class.clone()]));
                }
            }
            for (ti, &(s, e)) in toks.iter().enumerate() {
                if s <= f.body_start || s >= f.body_end || in_ranges(&art.test_ranges, s) {
                    continue;
                }
                let tok = &art.code[s..e];
                if tok == b"lock" || tok == b"read" || tok == b"write" {
                    continue;
                }
                if art.code.get(e) != Some(&b'(') || !followable_call(&art.code, s) {
                    continue;
                }
                // a nested fn's own declaration is not a call
                if ti > 0 {
                    let (ps, pe) = toks[ti - 1];
                    if &art.code[ps..pe] == b"fn" {
                        continue;
                    }
                }
                let name = String::from_utf8_lossy(tok);
                let Some(info) = index.get(name.as_ref()) else {
                    continue;
                };
                if info.classes.is_empty() {
                    continue;
                }
                events.push((s, info.classes.clone()));
                if info.returns_guard && stmt_is_let(&art.code, stmt_start(&art.code, s)) {
                    let live = enclosing_block_end(&art.code, &art.braces, s);
                    for c in &info.classes {
                        holdings.push((s, live, c.clone()));
                    }
                }
            }
            for &(hp, hend, ref hclass) in &holdings {
                for &(ep, ref eclasses) in &events {
                    if ep <= hp || ep > hend {
                        continue;
                    }
                    for c in eclasses {
                        if c == hclass {
                            continue; // re-entry is the runtime checker's job
                        }
                        adj.entry(hclass.clone()).or_default().push(c.clone());
                        if seen.insert((hclass.clone(), c.clone())) {
                            let site = Site {
                                line: line_at(&art.starts, ep),
                                fn_decl_line: f.decl_line,
                                art: ai,
                            };
                            pairs.push((hclass.clone(), c.clone(), site));
                        }
                    }
                }
            }
        }
    }

    // an edge u -> v closes a cycle iff v already reaches u
    let mut findings = Vec::new();
    for (u, v, site) in pairs {
        let Some(path) = reaches(&adj, &v, &u) else {
            continue;
        };
        let art = &arts[site.art];
        let raw_lines: Vec<&str> = art.src.split('\n').collect();
        if art.supp.covers(&raw_lines, "lock-order", site.line)
            || art.supp.covers(&raw_lines, "lock-order", site.fn_decl_line)
        {
            continue;
        }
        findings.push(Finding {
            file: art.rel.clone(),
            line: site.line,
            rule: "lock-order",
            msg: format!(
                "lock-order cycle: `{v}` is acquired while `{u}` is held, closing the cycle \
                 {u} -> {}",
                path.join(" -> ")
            ),
        });
    }
    findings
}

/// BFS path from `from` to `to` in the acquisition graph, inclusive of
/// both endpoints (`from == to` is the trivial self-path).
fn reaches(adj: &HashMap<String, Vec<String>>, from: &str, to: &str) -> Option<Vec<String>> {
    if from == to {
        return Some(vec![from.to_string()]);
    }
    let mut parent: HashMap<&str, &str> = HashMap::new();
    let mut queue: VecDeque<&str> = VecDeque::new();
    queue.push_back(from);
    while let Some(n) = queue.pop_front() {
        for m in adj.get(n).map(Vec::as_slice).unwrap_or(&[]) {
            let m = m.as_str();
            if m == to {
                let mut path = vec![m, n];
                let mut cur = n;
                while let Some(&p) = parent.get(cur) {
                    path.push(p);
                    cur = p;
                }
                path.reverse();
                return Some(path.into_iter().map(String::from).collect());
            }
            if m != from && !parent.contains_key(m) {
                parent.insert(m, n);
                queue.push_back(m);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    const KNOBS: &[&str] = &["WATERSIC_THREADS", "WATERSIC_LOG"];

    fn lint(rel: &str, src: &str) -> Vec<Finding> {
        let knobs: Vec<String> = KNOBS.iter().map(|s| s.to_string()).collect();
        lint_source(rel, src, &knobs)
    }

    fn rules(f: &[Finding]) -> Vec<&'static str> {
        f.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn unsafe_rule_fires_and_passes() {
        let f = lint("rust/src/x.rs", include_str!("../fixtures/fail_unsafe.rs"));
        assert!(rules(&f).contains(&"unsafe-safety"), "{f:?}");
        let f = lint("rust/src/x.rs", include_str!("../fixtures/pass_unsafe.rs"));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn fma_rule_scoped_to_linalg() {
        let src = include_str!("../fixtures/fail_fma.rs");
        let f = lint("rust/src/linalg/x.rs", src);
        assert!(rules(&f).contains(&"no-fma"), "{f:?}");
        // the same tokens outside linalg/ are fine
        let f = lint("rust/src/model/x.rs", src);
        assert!(!rules(&f).contains(&"no-fma"), "{f:?}");
        let f = lint(
            "rust/src/linalg/x.rs",
            include_str!("../fixtures/pass_fma.rs"),
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn panic_rule_scoped_to_untrusted_surfaces() {
        let src = include_str!("../fixtures/fail_panic.rs");
        let f = lint("rust/src/runtime/server.rs", src);
        let n = rules(&f)
            .iter()
            .filter(|r| **r == "no-panic-untrusted")
            .count();
        assert_eq!(n, 3, "unwrap + expect + panic! should all fire: {f:?}");
        // not an untrusted surface -> no findings
        let f = lint("rust/src/eval/mod.rs", src);
        assert!(!rules(&f).contains(&"no-panic-untrusted"), "{f:?}");
        let f = lint(
            "rust/src/runtime/server.rs",
            include_str!("../fixtures/pass_panic.rs"),
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn partial_cmp_rule_fires_everywhere() {
        let f = lint(
            "rust/src/model/x.rs",
            include_str!("../fixtures/fail_partial_cmp.rs"),
        );
        assert!(rules(&f).contains(&"no-partial-cmp-unwrap"), "{f:?}");
        let f = lint(
            "rust/src/model/x.rs",
            include_str!("../fixtures/pass_partial_cmp.rs"),
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn env_rule_catches_direct_reads_and_unknown_knobs() {
        let f = lint("rust/src/x.rs", include_str!("../fixtures/fail_env.rs"));
        let n = rules(&f).iter().filter(|r| **r == "env-registry").count();
        assert_eq!(n, 2, "direct read + unregistered literal: {f:?}");
        let f = lint("rust/src/x.rs", include_str!("../fixtures/pass_env.rs"));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn readme_knob_mentions_tokenize_and_skip_bare_prefixes() {
        let text = "set `WATERSIC_SERVE_QUEUE=64` (or any `WATERSIC_*` knob)\n\
                    WATERSIC_FAULT='read=partial'";
        let got = doc_knob_mentions(text);
        let want = vec![
            (1, "WATERSIC_SERVE_QUEUE".to_string()),
            (2, "WATERSIC_FAULT".to_string()),
        ];
        assert_eq!(got, want);
    }

    #[test]
    fn suppressions_cover_and_malformed_ones_fail() {
        let f = lint("rust/src/x.rs", include_str!("../fixtures/pass_allow.rs"));
        assert!(f.is_empty(), "{f:?}");
        let f = lint("rust/src/x.rs", include_str!("../fixtures/fail_allow.rs"));
        let n = rules(&f).iter().filter(|r| **r == "lint-allow").count();
        assert_eq!(n, 2, "unknown rule + missing reason: {f:?}");
        // a malformed allow does NOT suppress the violation under it
        assert!(rules(&f).contains(&"unsafe-safety"), "{f:?}");
    }

    #[test]
    fn raw_sync_rule_scoped_to_sync_module() {
        let src = include_str!("../fixtures/fail_raw_sync.rs");
        let f = lint("rust/src/x.rs", src);
        let n = rules(&f).iter().filter(|r| **r == "no-raw-sync").count();
        assert_eq!(n, 6, "three import idents + three field types: {f:?}");
        // the wrappers' own home is the one sanctioned user
        let f = lint(SYNC_FILE, src);
        assert!(f.is_empty(), "{f:?}");
        let f = lint("rust/src/x.rs", include_str!("../fixtures/pass_raw_sync.rs"));
        assert!(f.is_empty(), "{f:?}");
    }

    fn lock_order(rel: &str, src: &str) -> Vec<Finding> {
        lock_order_findings(&[(rel.to_string(), src.to_string())])
    }

    #[test]
    fn lock_order_cycles_fire_and_consistent_order_passes() {
        let src = include_str!("../fixtures/fail_lock_order.rs");
        let f = lock_order("rust/src/a.rs", src);
        let n = rules(&f).iter().filter(|r| **r == "lock-order").count();
        assert_eq!(n, 4, "two direct + two helper-mediated edges: {f:?}");
        let f = lock_order("rust/src/a.rs", include_str!("../fixtures/pass_lock_order.rs"));
        assert!(f.is_empty(), "{f:?}");
        // the wrappers' own home is exempt (its guts nest freely)
        let f = lock_order(SYNC_FILE, src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn lock_order_follows_helpers_across_files() {
        let caller = "fn outer() -> u32 { let d = D.lock(); helper() }\n";
        let helper = "fn helper() -> u32 { *C.lock() }\n\
                      fn other() { let c = C.lock(); let d = D.lock(); }\n";
        let f = lock_order_findings(&[
            ("rust/src/a.rs".to_string(), caller.to_string()),
            ("rust/src/b.rs".to_string(), helper.to_string()),
        ]);
        assert_eq!(rules(&f), vec!["lock-order", "lock-order"], "{f:?}");
    }

    #[test]
    fn reactor_blocking_rule_scoped_to_reactor() {
        let src = include_str!("../fixtures/fail_reactor_blocking.rs");
        let f = lint(REACTOR_FILE, src);
        let n = rules(&f).iter().filter(|r| **r == "reactor-blocking").count();
        assert_eq!(n, 5, "sleep, read, write, mode flip, wait-under-guard: {f:?}");
        assert_eq!(f.len(), 5, "only reactor-blocking fires: {f:?}");
        // the same code off the event loop is legal
        let f = lint("rust/src/runtime/x.rs", src);
        assert!(f.is_empty(), "{f:?}");
        let f = lint(
            REACTOR_FILE,
            include_str!("../fixtures/pass_reactor_blocking.rs"),
        );
        assert!(f.is_empty(), "{f:?}");
    }

    fn bench_sources(files: &[(&str, &str)]) -> Vec<(String, String)> {
        files
            .iter()
            .map(|(rel, src)| (rel.to_string(), src.to_string()))
            .collect()
    }

    #[test]
    fn bench_json_sync_fires_and_passes() {
        let ok = bench_sources(&[(
            "benches/bench_ok.rs",
            include_str!("../fixtures/pass_bench_sync.rs"),
        )]);
        let ci = include_str!("../fixtures/pass_bench_sync.yml");
        let f = bench_json_sync_findings(Some(("ci.yml", ci)), &ok);
        assert!(f.is_empty(), "{f:?}");

        let bad = bench_sources(&[
            (
                "benches/bench_fake.rs",
                include_str!("../fixtures/fail_bench_sync.rs"),
            ),
            (
                "benches/bench_other.rs",
                include_str!("../fixtures/fail_bench_sync_noconst.rs"),
            ),
        ]);
        let ci = include_str!("../fixtures/fail_bench_sync.yml");
        let f = bench_json_sync_findings(Some(("ci.yml", ci)), &bad);
        let n = rules(&f).iter().filter(|r| **r == "bench-json-sync").count();
        assert_eq!(
            n, 6,
            "unemitted gate + 2 ungrepped gates + missing const + ghost grep \
             + orphan json: {f:?}"
        );
        assert_eq!(f.len(), 6, "only bench-json-sync fires: {f:?}");
    }

    #[test]
    fn bench_json_sync_without_ci_checks_gates_only() {
        let bad = bench_sources(&[
            (
                "benches/bench_fake.rs",
                include_str!("../fixtures/fail_bench_sync.rs"),
            ),
            (
                "benches/bench_other.rs",
                include_str!("../fixtures/fail_bench_sync_noconst.rs"),
            ),
        ]);
        let f = bench_json_sync_findings(None, &bad);
        let n = rules(&f).iter().filter(|r| **r == "bench-json-sync").count();
        assert_eq!(n, 2, "unemitted gate + missing const only: {f:?}");
        // a file outside benches/ is never a surface, whatever it contains
        let stray = bench_sources(&[(
            "rust/src/x.rs",
            include_str!("../fixtures/fail_bench_sync_noconst.rs"),
        )]);
        assert!(bench_json_sync_findings(None, &stray).is_empty());
    }

    #[test]
    fn bench_entry_templates_match_anchored_wildcards() {
        assert!(template_matches("speedup decode {window}", "speedup decode 256"));
        assert!(!template_matches("speedup decode {window}", "speedup coded decode 256"));
        assert!(template_matches("trsm {a}x{n}", "trsm 256x512"));
        assert!(template_matches("matmul {n}³", "matmul 512³"));
        assert!(template_matches("alpha", "alpha"));
        assert!(!template_matches("alpha", "alphabet"));
        assert!(!template_matches("{n} tail", "head 256"));
    }

    #[test]
    fn ci_bench_greps_extract_entry_and_json() {
        let ci = "  grep -q '\"chol 1024\"' BENCH_linalg.json\n\
                  grep -q 'gate ok: overload' bench.log\n\
                  ! grep -q ' 0 shed ' open.log\n";
        let got = ci_bench_greps(ci);
        assert_eq!(
            got,
            vec![(1, "chol 1024".to_string(), "BENCH_linalg.json".to_string())]
        );
    }

    #[test]
    fn formats_carry_identical_findings() {
        let f = Finding {
            file: "rust/src/x.rs".to_string(),
            line: 7,
            rule: "no-raw-sync",
            msg: "50% \"raw\"\nnewline".to_string(),
        };
        assert_eq!(
            render_finding(&f, Format::Text),
            "rust/src/x.rs:7: [no-raw-sync] 50% \"raw\"\nnewline"
        );
        assert_eq!(
            render_finding(&f, Format::Github),
            "::error file=rust/src/x.rs,line=7,title=watersic-lint no-raw-sync\
             ::50%25 \"raw\"%0Anewline"
        );
        assert_eq!(
            render_finding(&f, Format::Json),
            "  {\"file\": \"rust/src/x.rs\", \"line\": 7, \"rule\": \"no-raw-sync\", \
             \"msg\": \"50% \\\"raw\\\"\\nnewline\"}"
        );
    }

    #[test]
    fn cfg_all_test_regions_are_exempt() {
        let src = "#[cfg(all(test, feature = \"f\"))]\nmod t {\n    fn f() { x.unwrap(); } \n}\n";
        let f = lint("rust/src/runtime/server.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn code_view_blanks_strings_and_comments() {
        let src = "let s = \"unsafe .unwrap()\"; // unsafe here too\n";
        let (code, comments) = code_view(src);
        assert!(!subslice(&code, b"unwrap"));
        assert!(!subslice(&code, b"unsafe"));
        // positions and line structure survive; the line comment span
        // is reported
        assert_eq!(code.len(), src.len());
        assert_eq!(comments.len(), 1);
    }

    #[test]
    fn cfg_test_regions_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { x.unwrap(); }\n}\n";
        let f = lint("rust/src/runtime/server.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    /// The real tree must be clean — the same invariant CI enforces
    /// with `cargo run -p xtask -- lint`.
    #[test]
    fn repo_tree_is_clean() {
        let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/../.."));
        let (findings, nfiles) = run_lint(root).expect("lint run");
        assert!(findings.is_empty(), "{findings:#?}");
        assert!(nfiles > 20, "scanned only {nfiles} files");
    }
}
