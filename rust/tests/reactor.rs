//! Front-door integration tests over real TCP sockets: the event-driven
//! reactor and the threaded fallback must speak the same line-JSON
//! protocol, enforce the connection cap and idle/write timeouts, shed
//! overload with a well-formed `retry_after_ms` hint, cancel work whose
//! client disconnected (freeing its KV bytes for queued requests),
//! honor per-request deadlines over the wire, and drain cleanly on
//! shutdown.
//!
//! Own binary: each test runs a live server + front-door thread pair
//! over `127.0.0.1:0` sockets.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use watersic::experiments::synthetic_tiny_setup;
use watersic::linalg::gemm::Precision;
use watersic::model::transformer::KvCache;
use watersic::model::weights::PackedWeights;
use watersic::model::ModelConfig;
use watersic::runtime::reactor::{self, ReactorOpts};
use watersic::runtime::{ServeOpts, Server};
use watersic::util::json::Json;

/// Deterministic, env-independent scheduler limits.  `max_steps` is
/// huge so tests can park a generation "forever" and cancel it.
fn base_opts() -> ServeOpts {
    ServeOpts {
        batch_max: 4,
        flush: Duration::from_micros(0),
        kv_budget: 1 << 30,
        max_steps: 1 << 20,
        queue_max: 64,
        deadline: None,
    }
}

/// An unquantized tiny-model server (zero artifacts, random weights —
/// the same setup the CLI `serve --model tiny` path uses).
fn tiny_server(opts: ServeOpts) -> Arc<Server> {
    let (cfg, teacher, _) = synthetic_tiny_setup();
    let packed = PackedWeights::new(&cfg, teacher, Precision::from_env());
    Arc::new(Server::start(cfg, packed, opts))
}

fn ropts(max_conns: usize, idle_ms: u64, write_ms: u64) -> ReactorOpts {
    ReactorOpts {
        max_conns,
        idle: Duration::from_millis(idle_ms),
        write_stall: Duration::from_millis(write_ms),
    }
}

/// Run a front door over `127.0.0.1:0`, hand the client body its
/// address (plus the server and stop flag), then stop and assert the
/// front door exits cleanly.
fn with_front_door<F>(server: &Arc<Server>, ropts: ReactorOpts, threaded: bool, body: F)
where
    F: FnOnce(SocketAddr, &Server, &AtomicBool),
{
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        let door = s.spawn(|| {
            if threaded {
                reactor::serve_threaded(server, &listener, &ropts, &stop)
            } else {
                reactor::serve(server, &listener, &ropts, &stop)
            }
        });
        body(addr, server, &stop);
        stop.store(true, Ordering::Relaxed);
        door.join().unwrap().unwrap();
    });
}

fn connect(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn send_line(stream: &mut TcpStream, line: &str) {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
}

/// Read one response line and parse it; panics on EOF.
fn read_json(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    let n = reader.read_line(&mut line).unwrap();
    assert!(n > 0, "connection closed before a response arrived");
    Json::parse(line.trim()).unwrap()
}

/// `true` iff the peer closed the connection (clean EOF).
fn at_eof(reader: &mut BufReader<TcpStream>) -> bool {
    let mut line = String::new();
    matches!(reader.read_line(&mut line), Ok(0))
}

fn spin_until(what: &str, f: impl Fn() -> bool) {
    let t0 = Instant::now();
    while !f() {
        assert!(t0.elapsed() < Duration::from_secs(30), "timed out: {what}");
        std::thread::sleep(Duration::from_micros(200));
    }
}

#[test]
fn reactor_roundtrip_pipelining_and_malformed_lines() {
    let server = tiny_server(base_opts());
    with_front_door(&server, ropts(16, 10_000, 10_000), false, |addr, _, _| {
        let (mut c, mut r) = connect(addr);

        // score
        send_line(&mut c, "{\"tokens\": [1, 2, 3]}");
        let j = read_json(&mut r);
        assert_eq!(j.req("len").unwrap().as_usize().unwrap(), 3);
        assert!(j.req("nll").unwrap().as_f64().unwrap().is_finite());
        assert!(j.get("error").is_none());

        // generation
        send_line(&mut c, "{\"prompt\": [1, 2], \"steps\": 3}");
        let j = read_json(&mut r);
        assert_eq!(j.req("steps").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.req("tokens").unwrap().as_arr().unwrap().len(), 5);

        // steps: 0 echoes the prompt without touching the scheduler
        send_line(&mut c, "{\"prompt\": [7], \"steps\": 0}");
        let j = read_json(&mut r);
        assert_eq!(j.req("tokens").unwrap().as_arr().unwrap().len(), 1);

        // malformed JSON answers an error on the same connection
        send_line(&mut c, "this is not json");
        let j = read_json(&mut r);
        assert!(j.get("error").is_some());

        // pipelining: two requests in one write, two responses in order
        c.write_all(b"{\"tokens\": [5, 6]}\n{\"tokens\": [1, 2, 3, 4]}\n")
            .unwrap();
        let first = read_json(&mut r);
        let second = read_json(&mut r);
        assert_eq!(first.req("len").unwrap().as_usize().unwrap(), 2);
        assert_eq!(second.req("len").unwrap().as_usize().unwrap(), 4);

        // a non-utf-8 line gets a JSON error, then the conn closes
        let (mut c2, mut r2) = connect(addr);
        c2.write_all(&[b'{', 0xff, 0xfe, b'\n']).unwrap();
        let j = read_json(&mut r2);
        assert!(j.req("error").unwrap().as_str().unwrap().contains("utf-8"));
        assert!(at_eof(&mut r2));

        // an unbounded line (no newline) is rejected, then the conn
        // closes — one client cannot grow server memory forever
        // sized to cross the limit only near the write's end, so the
        // kernel buffers absorb the tail and the write never races the
        // server's close
        let (mut c3, mut r3) = connect(addr);
        let blob = vec![b'x'; (1 << 20) + 4096];
        c3.write_all(&blob).unwrap();
        let j = read_json(&mut r3);
        assert!(j.req("error").unwrap().as_str().unwrap().contains("too long"));
        assert!(at_eof(&mut r3));
    });
}

#[test]
fn reactor_connection_cap_sheds_with_retry_after() {
    let server = tiny_server(base_opts());
    with_front_door(&server, ropts(1, 10_000, 10_000), false, |addr, _, _| {
        // occupy the single slot (roundtrip proves it is registered)
        let (mut a, mut ra) = connect(addr);
        send_line(&mut a, "{\"tokens\": [1, 2]}");
        assert_eq!(read_json(&mut ra).req("len").unwrap().as_usize().unwrap(), 2);

        // the next connection is shed immediately with a retry hint
        let (_b, mut rb) = connect(addr);
        let j = read_json(&mut rb);
        assert_eq!(j.req("error").unwrap().as_str().unwrap(), "overloaded");
        assert!(j.req("retry_after_ms").unwrap().as_usize().unwrap() >= 1);
        assert!(at_eof(&mut rb));

        // the admitted connection is unaffected
        send_line(&mut a, "{\"tokens\": [3, 4, 5]}");
        assert_eq!(read_json(&mut ra).req("len").unwrap().as_usize().unwrap(), 3);
    });
}

#[test]
fn reactor_idle_timeout_reaps_slow_loris() {
    let server = tiny_server(base_opts());
    with_front_door(&server, ropts(16, 150, 10_000), false, |addr, _, _| {
        // half a request, then silence: the idle timeout must close it
        let (mut c, mut r) = connect(addr);
        c.write_all(b"{\"tok").unwrap();
        let t0 = Instant::now();
        assert!(at_eof(&mut r), "slow-loris connection was never reaped");
        assert!(t0.elapsed() < Duration::from_secs(10));

        // and the server still serves fresh connections afterwards
        let (mut c2, mut r2) = connect(addr);
        send_line(&mut c2, "{\"tokens\": [1, 2]}");
        assert_eq!(read_json(&mut r2).req("len").unwrap().as_usize().unwrap(), 2);
    });
}

#[test]
fn reactor_disconnect_mid_generation_frees_kv_for_queued_request() {
    // budget for exactly one full-context sequence: B cannot start
    // until A's KV bytes are freed
    let cfg = ModelConfig::tiny_test();
    let mut opts = base_opts();
    opts.kv_budget = KvCache::bytes_for(&cfg, cfg.ctx);
    let server = tiny_server(opts);
    with_front_door(&server, ropts(16, 10_000, 10_000), false, |addr, srv, _| {
        // A: a generation that would run ~forever, holding the budget
        let (mut a, _ra) = connect(addr);
        send_line(&mut a, "{\"prompt\": [1, 2], \"steps\": 1048576}");
        spin_until("A decoding", || srv.stats().decode_steps > 0);

        // B: queued behind A (strict FIFO + no KV headroom)
        let (mut b, mut rb) = connect(addr);
        send_line(&mut b, "{\"prompt\": [3, 4], \"steps\": 3}");

        // A's client vanishes: the reactor drops the handle, the
        // scheduler cancels the sequence and frees its KV bytes…
        drop(a);
        drop(_ra);

        // …which must let B run to completion
        let j = read_json(&mut rb);
        assert!(j.get("error").is_none(), "B errored: {}", j.to_string_compact());
        assert_eq!(j.req("steps").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.req("tokens").unwrap().as_arr().unwrap().len(), 5);
        spin_until("A cancelled", || srv.stats().gen_cancelled == 1);
    });
}

#[test]
fn reactor_deadline_over_the_wire_cancels_mid_flight() {
    let server = tiny_server(base_opts());
    with_front_door(&server, ropts(16, 10_000, 10_000), false, |addr, _, _| {
        let (mut c, mut r) = connect(addr);
        send_line(&mut c, "{\"prompt\": [1, 2], \"steps\": 1048576, \"deadline_ms\": 50}");
        let j = read_json(&mut r);
        assert!(j.get("error").is_none(), "deadline: {}", j.to_string_compact());
        assert!(j.get("cancelled").is_some(), "missing cancelled marker");
        // partial output: prompt + at least one decoded token, far
        // fewer than requested
        let toks = j.req("tokens").unwrap().as_arr().unwrap().len();
        assert!(toks >= 2 && toks < 1048576, "got {toks} tokens");
    });
}

#[test]
fn reactor_graceful_shutdown_drains_in_flight_generation() {
    let server = tiny_server(base_opts());
    with_front_door(&server, ropts(16, 10_000, 10_000), false, |addr, srv, stop| {
        let (mut c, mut r) = connect(addr);
        send_line(&mut c, "{\"prompt\": [1, 2], \"steps\": 4000}");
        spin_until("decoding", || srv.stats().decode_steps > 0);

        // shutdown lands mid-generation: the response must still
        // arrive complete, then the server closes the connection
        stop.store(true, Ordering::Relaxed);
        let j = read_json(&mut r);
        assert!(j.get("error").is_none(), "drain: {}", j.to_string_compact());
        assert_eq!(j.req("steps").unwrap().as_usize().unwrap(), 4000);
        assert_eq!(j.req("tokens").unwrap().as_arr().unwrap().len(), 4002);
        assert!(at_eof(&mut r));
    });
}

#[test]
fn threaded_fallback_roundtrip_and_idle_timeout() {
    let server = tiny_server(base_opts());
    with_front_door(&server, ropts(16, 300, 2_000), true, |addr, _, _| {
        // protocol parity with the reactor path
        let (mut c, mut r) = connect(addr);
        send_line(&mut c, "{\"tokens\": [1, 2, 3]}");
        assert_eq!(read_json(&mut r).req("len").unwrap().as_usize().unwrap(), 3);
        send_line(&mut c, "{\"prompt\": [1], \"steps\": 2}");
        assert_eq!(read_json(&mut r).req("steps").unwrap().as_usize().unwrap(), 2);

        // connect-and-sleep client: `set_read_timeout` must reap it
        // instead of pinning a handler thread forever
        let (_idle, mut ridle) = connect(addr);
        let t0 = Instant::now();
        assert!(at_eof(&mut ridle), "idle connection was never reaped");
        assert!(t0.elapsed() >= Duration::from_millis(200), "reaped too early");
        assert!(t0.elapsed() < Duration::from_secs(10));
    });
}

#[test]
fn threaded_fallback_sheds_over_connection_cap() {
    let server = tiny_server(base_opts());
    with_front_door(&server, ropts(1, 500, 2_000), true, |addr, _, _| {
        let (mut a, mut ra) = connect(addr);
        send_line(&mut a, "{\"tokens\": [1, 2]}");
        assert_eq!(read_json(&mut ra).req("len").unwrap().as_usize().unwrap(), 2);

        let (_b, mut rb) = connect(addr);
        let j = read_json(&mut rb);
        assert_eq!(j.req("error").unwrap().as_str().unwrap(), "overloaded");
        assert!(j.req("retry_after_ms").unwrap().as_usize().unwrap() >= 1);
        assert!(at_eof(&mut rb));
    });
}
