//! Regression test for the capture-path probs scatter: a counting
//! global allocator bounds the peak transient footprint of a capture
//! forward.  Before the fix, every (batch, head) t×t probability block
//! was staged in `head_outs` and then copied into the flat capture
//! buffer, transiently doubling the probs footprint (peak ≳ 2× the
//! flat buffer); after the fix each task writes its disjoint slice of
//! `probs_flat` directly, so the peak stays ≈ 1× plus panel overhead.
//!
//! This file is its own test binary (see Cargo.toml) so the allocator
//! instrumentation cannot race with unrelated tests.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use watersic::model::transformer::{forward, ForwardOpts};
use watersic::model::weights::Weights;
use watersic::model::ModelConfig;

struct CountingAlloc;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

// SAFETY: defers every allocation to `System` and only adds atomic
// counter updates, so the GlobalAlloc contract is System's own.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: contract forwarded verbatim to `System.alloc`.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::SeqCst) + layout.size();
            PEAK.fetch_max(live, Ordering::SeqCst);
        }
        p
    }

    // SAFETY: contract forwarded verbatim to `System.dealloc`.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE.fetch_sub(layout.size(), Ordering::SeqCst);
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn capture_does_not_double_buffer_probs() {
    // small model, long context: the (b, h) t×t prob blocks dominate
    // every other allocation by an order of magnitude
    let cfg = ModelConfig {
        vocab: 16,
        d_model: 8,
        n_heads: 2,
        d_ff: 16,
        ctx: 384,
        ..ModelConfig::tiny_test()
    };
    let b = 1;
    let w = Weights::random(&cfg, 3);
    let tokens: Vec<i32> = (0..b * cfg.ctx)
        .map(|i| (i % cfg.vocab) as i32)
        .collect();

    // warm up: spawns the thread pool and any lazily allocated state so
    // the measured run only pays for the forward itself
    let _ = forward(&cfg, &w, &tokens, b, cfg.ctx, &ForwardOpts::default());

    let flat_bytes = b * cfg.n_heads * cfg.ctx * cfg.ctx * 8;
    let base = LIVE.load(Ordering::SeqCst);
    PEAK.store(base, Ordering::SeqCst);
    let out = forward(
        &cfg,
        &w,
        &tokens,
        b,
        cfg.ctx,
        &ForwardOpts {
            capture: true,
            tape: false,
            ..ForwardOpts::default()
        },
    );
    let peak = PEAK.load(Ordering::SeqCst).saturating_sub(base);
    let cap = out.capture.expect("capture requested");
    assert_eq!(cap.attn_probs[0].len(), b * cfg.n_heads * cfg.ctx * cfg.ctx);

    // 1.8× leaves generous room for activation panels and captures on
    // top of the flat buffer, but is far below the ≥2.3× the staged
    // double-buffering needed
    assert!(
        peak < flat_bytes * 9 / 5,
        "capture forward peaked at {peak} B vs {flat_bytes} B of prob \
         blocks — transient double-buffering is back?"
    );
}
