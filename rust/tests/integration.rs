//! Integration tests over the built artifacts: PJRT runtime vs native
//! oracle, the full pipeline on the real trained model, container
//! round-trips through the filesystem, and the theory gap on a
//! moderately sized instance.  Skipped gracefully when `make artifacts`
//! has not been run.

use watersic::coordinator::container::Container;
use watersic::coordinator::{quantize_model, Algo};
use watersic::experiments::{llm::pipeline_opts, Ctx};
use watersic::linalg::chol::cholesky;
use watersic::linalg::gemm::matmul;
use watersic::linalg::Mat;
use watersic::quant::waterfilling::{ar1_sigma, r_wf, spectrum, SHAPING_GAP_BITS};
use watersic::quant::zsic::{geomean_diag, watersic_alphas, zsic};
use watersic::runtime::ZsicArtifact;
use watersic::util::rng::Rng;

fn ctx_or_skip() -> Option<Ctx> {
    let ctx = Ctx::new(true, true).ok()?;
    if !ctx.artifacts.join("manifest.json").exists() {
        eprintln!("skipping integration test: run `make artifacts`");
        return None;
    }
    Some(ctx)
}

#[test]
fn pjrt_zsic_matches_native_on_all_exported_shapes() {
    let Some(ctx) = ctx_or_skip() else { return };
    let Some(engine) = &ctx.engine else { return };
    let mut rng = Rng::new(9);
    for (a, n) in [(64usize, 64usize), (256, 64), (64, 256)] {
        let sigma = ar1_sigma(n, 0.7);
        let l = cholesky(&sigma).unwrap();
        let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
        let y = matmul(&w, &l);
        let alphas = watersic_alphas(&l, 0.25);
        for lmmse in [false, true] {
            let native = zsic(&y, &l, &alphas, lmmse, None);
            let art = engine
                .run_zsic(ZsicArtifact { a, n, lmmse }, &y, &l, &alphas)
                .unwrap();
            let mism = native.z.iter().zip(&art.z).filter(|(x, y)| x != y).count();
            assert!(
                (mism as f64) < 0.005 * (a * n) as f64,
                "{a}x{n} lmmse={lmmse}: {mism} mismatches"
            );
        }
    }
}

#[test]
fn pipeline_on_trained_model_beats_hptq_at_2_bits() {
    let Some(ctx) = ctx_or_skip() else { return };
    let (cfg, teacher) = ctx.load_model("picollama_s").unwrap();
    let wiki = ctx.load_corpus("wiki").unwrap();
    let windows = wiki.eval_windows(16, cfg.ctx, 42);

    let run = |algo| {
        let opts = pipeline_opts(&ctx, algo, 2.0, false);
        let qm =
            quantize_model(&cfg, &teacher, &wiki, &opts, ctx.engine.as_ref())
                .unwrap();
        (
            qm.report.avg_rate,
            watersic::eval::perplexity_native(&cfg, &qm.student, &windows),
        )
    };
    let (rate_ws, ppl_ws) = run(Algo::WaterSic);
    let (rate_hg, ppl_hg) = run(Algo::HuffGptq);
    assert!((rate_ws - 2.0).abs() < 0.2, "rate {rate_ws}");
    assert!((rate_hg - 2.0).abs() < 0.2, "rate {rate_hg}");
    assert!(
        ppl_ws < ppl_hg,
        "WaterSIC ({ppl_ws:.3}) must beat Huffman-GPTQ ({ppl_hg:.3}) at 2 bits"
    );
    // usable model: far below the uniform-byte PPL of 256
    assert!(ppl_ws < 16.0, "2-bit model unusable: PPL {ppl_ws}");
}

#[test]
fn container_roundtrip_through_filesystem() {
    let Some(ctx) = ctx_or_skip() else { return };
    let (cfg, teacher) = ctx.load_model("picollama_s").unwrap();
    let wiki = ctx.load_corpus("wiki").unwrap();
    let opts = pipeline_opts(&ctx, Algo::WaterSic, 3.0, false);
    let qm =
        quantize_model(&cfg, &teacher, &wiki, &opts, ctx.engine.as_ref()).unwrap();

    let path = std::env::temp_dir().join("wsic_integration.wsic");
    Container::new(&cfg.name, qm.quants.clone())
        .save(&path)
        .unwrap();
    let loaded = Container::load(&path).unwrap();
    assert_eq!(loaded.model_name, cfg.name);
    for (name, q) in &qm.quants {
        let q2 = &loaded.quants[name];
        assert_eq!(q.z, q2.z, "{name} codes must be bit-identical");
        let d = q.dequant().sub(&q2.dequant()).max_abs();
        assert!(d < 1e-5, "{name}: dequant drift {d}");
    }
}

#[test]
fn forward_artifact_matches_native_after_quantization() {
    let Some(ctx) = ctx_or_skip() else { return };
    let Some(engine) = &ctx.engine else { return };
    let (cfg, teacher) = ctx.load_model("picollama_s").unwrap();
    let wiki = ctx.load_corpus("wiki").unwrap();
    let opts = pipeline_opts(&ctx, Algo::WaterSic, 2.5, false);
    let qm =
        quantize_model(&cfg, &teacher, &wiki, &opts, ctx.engine.as_ref()).unwrap();
    let windows = wiki.eval_windows(8, cfg.ctx, 7);
    let mut toks = Vec::new();
    for (i, _) in &windows {
        toks.extend_from_slice(i);
    }
    let rt = engine.run_forward(&cfg, &qm.student, &toks, 8).unwrap();
    let nat = watersic::model::transformer::forward(
        &cfg,
        &qm.student,
        &toks,
        8,
        cfg.ctx,
        &watersic::model::transformer::ForwardOpts::default(),
    )
    .logits;
    let mut max_rel = 0.0f64;
    for i in 0..rt.data.len() {
        max_rel =
            max_rel.max((rt.data[i] - nat.data[i]).abs() / nat.data[i].abs().max(1.0));
    }
    assert!(max_rel < 5e-3, "quantized forward mismatch {max_rel}");
}

#[test]
fn theory_gap_medium_instance() {
    // no artifacts needed; moderately sized to keep `cargo test` fast
    let (a, n) = (512usize, 64usize);
    let sigma = ar1_sigma(n, 0.95);
    let lam = spectrum(&sigma);
    let l = cholesky(&sigma).unwrap();
    let gm = geomean_diag(&l);
    let mut rng = Rng::new(31);
    let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
    let y = matmul(&w, &l);

    let measure = |alphas: &[f64]| {
        let out = zsic(&y, &l, alphas, false, None);
        let r = watersic::entropy::column_coded_rate(&out.z, a, n);
        let d =
            out.resid.data.iter().map(|x| x * x).sum::<f64>() / (a * n) as f64;
        r - r_wf(d, &lam, 1.0)
    };
    let alpha = 4.133 * 2f64.powf(-4.0); // ≈4-bit operating point
    let gap_ws = measure(&watersic_alphas(&l, alpha * gm));
    let gap_gq = measure(&vec![alpha; n]);
    // WaterSIC within ~0.15 bit of the 0.255 shaping constant; GPTQ
    // strictly worse on this strongly correlated source
    assert!(
        (gap_ws - SHAPING_GAP_BITS).abs() < 0.15,
        "WaterSIC gap {gap_ws:.3}"
    );
    // the AM/GM penalty for AR(1) ρ=0.95 at n=64 is ≈0.07 bit
    assert!(gap_gq > gap_ws + 0.04, "GPTQ gap {gap_gq:.3} vs WS {gap_ws:.3}");
}

// ---------------------------------------------------------------------
// Miri-tagged small-shape tests.  CI's Miri job runs exactly these
// (`cargo +nightly miri test --test integration miri_`): tiny shapes
// and ≤ 2 threads keep interpretation time bounded while still driving
// the unsafe pack/kernel/threadpool paths end to end (the backend
// selector forces the scalar rung under Miri — see `detect_backend`).
// They also run, near-instantly, as part of the normal suite.

fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    Mat::from_fn(a.rows, b.cols, |i, j| {
        (0..a.cols).map(|k| a[(i, k)] * b[(k, j)]).sum()
    })
}

#[test]
fn miri_gemm_small_matches_naive() {
    let mut rng = Rng::new(7);
    for (m, k, n) in [(1usize, 1usize, 1usize), (3, 5, 4), (7, 6, 9)] {
        let a = Mat::from_fn(m, k, |_, _| rng.gaussian());
        let b = Mat::from_fn(k, n, |_, _| rng.gaussian());
        let c = watersic::linalg::gemm::matmul_with_threads(&a, &b, 2);
        let r = naive_matmul(&a, &b);
        for (x, y) in c.data.iter().zip(&r.data) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }
}

#[test]
fn miri_prepacked_gemm_small_matches_naive() {
    use watersic::linalg::gemm::{matmul_prepacked_with, simd_backend, Precision, PrepackedB};
    let mut rng = Rng::new(11);
    let a = Mat::from_fn(5, 7, |_, _| rng.gaussian());
    let b = Mat::from_fn(7, 6, |_, _| rng.gaussian());
    let pb = PrepackedB::pack(&b, Precision::F64);
    let c = matmul_prepacked_with(&a, &pb, 2, simd_backend());
    let r = naive_matmul(&a, &b);
    for (x, y) in c.data.iter().zip(&r.data) {
        assert!((x - y).abs() < 1e-9, "{x} vs {y}");
    }
}

#[test]
fn miri_coded_gemm_small_matches_eager() {
    // the coded decode-inside-pack path at a tiny shape: bit-identical
    // to prepacking the eagerly dequantized operand, under 2 threads
    // (one sub-panel decode per task) and the Miri-forced scalar rung
    use watersic::linalg::gemm::{
        matmul_coded_with, matmul_prepacked_with, simd_backend, CodedPanel, CodedPart,
        Precision, PrepackedB,
    };
    let mut rng = Rng::new(13);
    let (rows, cols) = (6, 9); // storage: operand is the 9×6 transpose
    let z: Vec<i32> = (0..rows * cols)
        .map(|_| (rng.gaussian() * 4.0).round() as i32)
        .collect();
    let t: Vec<f64> = (0..rows).map(|_| rng.gaussian().abs() + 0.1).collect();
    let gammas: Vec<f64> = (0..cols).map(|_| rng.gaussian().abs() + 0.1).collect();
    let alphas: Vec<f64> = (0..cols).map(|_| rng.gaussian().abs() + 0.1).collect();
    let w = Mat::from_fn(rows, cols, |i, j| {
        ((t[i] * f64::from(z[i * cols + j])) * gammas[j]) * alphas[j]
    });
    let part = CodedPart {
        z: &z,
        t: &t,
        gammas: &gammas,
        alphas: &alphas,
        rows,
        cols,
    };
    let a = Mat::from_fn(4, cols, |_, _| rng.gaussian());
    for prec in [Precision::F64, Precision::F32] {
        let cp = CodedPanel::pack_nt_parts(&[part], prec).unwrap();
        let pb = PrepackedB::pack_nt(&w, prec);
        let c = matmul_coded_with(&a, &cp, 2, simd_backend());
        let r = matmul_prepacked_with(&a, &pb, 2, simd_backend());
        assert_eq!(c.data, r.data, "{prec:?}");
    }
}

#[test]
fn miri_cholesky_small_roundtrips() {
    use watersic::linalg::chol::{cholesky_with_threads, solve_lower};
    let n = 6;
    let sigma = ar1_sigma(n, 0.6);
    let l = cholesky_with_threads(&sigma, 2).unwrap();
    // L·Lᵀ reproduces Σ
    for i in 0..n {
        for j in 0..n {
            let s: f64 = (0..n).map(|k| l[(i, k)] * l[(j, k)]).sum();
            assert!((s - sigma[(i, j)]).abs() < 1e-9);
        }
    }
    // and the triangular solve inverts it
    let b: Vec<f64> = (0..n).map(|i| (i as f64) - 2.5).collect();
    let x = solve_lower(&l, &b);
    for i in 0..n {
        let s: f64 = (0..=i).map(|k| l[(i, k)] * x[k]).sum();
        assert!((s - b[i]).abs() < 1e-8);
    }
}
