//! Coded weight residency hardening: serving straight from quantized
//! codes (`WATERSIC_SERVE_WEIGHTS=coded`) must answer **byte-identically**
//! to the eager dequant load over any request mix, and a corrupted
//! `.wsic` container must surface as a clean error — never a panic,
//! never a silently wrong GEMM.  The corruption sweep mirrors the
//! container-level truncation sweeps: every byte-level truncation and
//! a bit flip at every byte position go through the *full* load
//! pipeline (parse → coded panel pack → forward) in both residency
//! modes; whenever both modes accept the bytes, their logits must
//! still agree bit-for-bit.
//!
//! One test mutates `WATERSIC_SERVE_WEIGHTS`, so this binary lives
//! outside the shared test harness and every test takes [`env_lock`]
//! for its whole body (a concurrent `setenv`/`getenv` pair is UB on
//! glibc — the same discipline as the serve parity binary).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::OnceLock;
use std::time::Duration;

use watersic::coordinator::container::Container;
use watersic::coordinator::quantize_model;
use watersic::experiments::{synthetic_tiny_opts, synthetic_tiny_setup};
use watersic::linalg::gemm::Precision;
use watersic::model::transformer::{forward_packed, ForwardOpts};
use watersic::model::weights::{PackedWeights, Weights};
use watersic::model::ModelConfig;
use watersic::runtime::server::{Server, ServeWeights};
use watersic::runtime::ServeOpts;
use watersic::util::rng::Rng;
use watersic::util::sync::{classes, TrackedMutex, TrackedMutexGuard};

/// `ServeOpts` with deterministic scheduler limits (env-independent).
fn opts(batch_max: usize, flush: Duration) -> ServeOpts {
    ServeOpts {
        batch_max,
        flush,
        kv_budget: 1 << 30,
        max_steps: 256,
        queue_max: 64,
        deadline: None,
    }
}

/// Serializes every test in this binary (see the module docs).
fn env_lock() -> TrackedMutexGuard<'static, ()> {
    static LOCK: TrackedMutex<()> = TrackedMutex::new(&classes::TEST_ENV, ());
    LOCK.lock()
}

/// Quantize the synthetic tiny model once per process.
fn setup() -> &'static (ModelConfig, Weights, Container) {
    static SETUP: OnceLock<(ModelConfig, Weights, Container)> = OnceLock::new();
    SETUP.get_or_init(|| {
        let (cfg, teacher, corpus) = synthetic_tiny_setup();
        let opts = synthetic_tiny_opts(3.0);
        let qm = quantize_model(&cfg, &teacher, &corpus, &opts, None).unwrap();
        let container = Container::new(&cfg.name, qm.quants.clone());
        // round-trip through the wire format, as the CLI load path does
        let container = Container::from_bytes(&container.to_bytes()).unwrap();
        (cfg, teacher, container)
    })
}

/// Deterministic request windows with a spread of lengths.
fn requests(cfg: &ModelConfig, n: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let len = 4 + (i % (cfg.ctx - 3));
            (0..len).map(|_| rng.below(cfg.vocab) as i32).collect()
        })
        .collect()
}

/// Serve one fixed request log — interleaved scores and greedy
/// generations — through a server in the given residency mode, and
/// return every response: score logits as raw bit patterns (NaN-safe
/// equality), generation token sequences verbatim.
fn serve_log(
    cfg: &ModelConfig,
    teacher: &Weights,
    container: &Container,
    prec: Precision,
    mode: ServeWeights,
) -> (Vec<Vec<u64>>, Vec<Vec<i32>>, usize, usize) {
    let server = Server::from_container_mode(
        cfg,
        teacher,
        container,
        prec,
        mode,
        opts(4, Duration::from_millis(50)),
    )
    .unwrap();
    let coded = server.coded_count();
    let resident = server.packed_bytes();
    let scores = requests(cfg, 8, 4242);
    let gens: Vec<(Vec<i32>, usize)> = vec![
        (vec![3, 1, 4, 1, 5, 9], 8), // crosses ctx = 12 mid-run
        (vec![2, 7, 1], 4),
        (vec![1; 12], 5), // saturated window from the first step
    ];
    // interleave submissions so scores and decode steps share batches
    let mut score_handles = Vec::new();
    let mut gen_handles = Vec::new();
    for (i, toks) in scores.iter().enumerate() {
        score_handles.push(server.submit(toks.clone()).unwrap());
        if i < gens.len() {
            gen_handles.push(
                server
                    .submit_generate(gens[i].0.clone(), gens[i].1)
                    .unwrap(),
            );
        }
    }
    let score_out: Vec<Vec<u64>> = score_handles
        .into_iter()
        .map(|h| {
            h.wait()
                .unwrap()
                .logits_last
                .iter()
                .map(|v| v.to_bits())
                .collect()
        })
        .collect();
    let gen_out: Vec<Vec<i32>> = gen_handles
        .into_iter()
        .map(|h| h.wait().unwrap().tokens)
        .collect();
    server.shutdown();
    (score_out, gen_out, coded, resident)
}

#[test]
fn coded_serve_byte_identical_to_dequant_over_mixed_log() {
    let _serial = env_lock();
    let (cfg, teacher, container) = setup();
    let prec = Precision::from_env();
    let (d_scores, d_gens, d_coded, d_resident) =
        serve_log(cfg, teacher, container, prec, ServeWeights::Dequant);
    let (c_scores, c_gens, c_coded, c_resident) =
        serve_log(cfg, teacher, container, prec, ServeWeights::Coded);
    assert_eq!(d_coded, 0, "dequant mode must hold no coded projections");
    assert!(
        c_coded > 0,
        "coded mode never engaged — every projection fell back dense"
    );
    assert!(
        c_resident < d_resident,
        "coded residency must shrink resident weight bytes \
         ({c_resident} vs {d_resident})"
    );
    // the whole point: same bits out, both precisions, any mix
    assert_eq!(d_scores, c_scores, "score logits diverged across residency");
    assert_eq!(d_gens, c_gens, "generated tokens diverged across residency");
}

#[test]
fn serve_weights_env_knob_selects_residency() {
    let _serial = env_lock();
    let (cfg, teacher, container) = setup();
    let prec = Precision::from_env();
    let old = watersic::util::env::string("WATERSIC_SERVE_WEIGHTS");
    std::env::set_var("WATERSIC_SERVE_WEIGHTS", "coded");
    assert_eq!(ServeWeights::from_env(), ServeWeights::Coded);
    let coded_server =
        Server::from_container(cfg, teacher, container, prec, opts(4, Duration::ZERO))
            .unwrap();
    assert!(coded_server.coded_count() > 0, "env knob did not engage");
    drop(coded_server);
    std::env::set_var("WATERSIC_SERVE_WEIGHTS", "dequant");
    assert_eq!(ServeWeights::from_env(), ServeWeights::Dequant);
    // unrecognized values must fall back, not abort the server
    std::env::set_var("WATERSIC_SERVE_WEIGHTS", "mmap");
    assert_eq!(ServeWeights::from_env(), ServeWeights::Dequant);
    match old {
        Some(v) => std::env::set_var("WATERSIC_SERVE_WEIGHTS", v),
        None => std::env::remove_var("WATERSIC_SERVE_WEIGHTS"),
    }
}

/// Load corrupted container bytes through both residency modes and a
/// short forward.  The contract: no panic anywhere in the pipeline;
/// a mode either rejects the bytes with a clean error or serves them,
/// and whenever *both* modes serve, their logits agree bit-for-bit
/// (bits, not values: a corrupted f32 scale can poison the weights
/// with NaN, which still must reconstruct identically on both paths).
fn check_corrupted(cfg: &ModelConfig, teacher: &Weights, bytes: &[u8], what: &str) {
    let outcome = catch_unwind(AssertUnwindSafe(|| -> Option<(Vec<u64>, Vec<u64>)> {
        let container = Container::from_bytes(bytes).ok()?; // clean parse rejection
        let prec = Precision::from_env();
        let dequant = PackedWeights::from_container(cfg, teacher, &container, prec);
        let coded = PackedWeights::from_container_coded(cfg, teacher, &container, prec);
        let (dequant, coded) = match (dequant, coded) {
            (Ok(d), Ok(c)) => (d, c),
            _ => return None, // clean load rejection (either mode)
        };
        let toks = [3i32, 1, 4, 1, 5, 9, 2, 6];
        let bits = |pw: &PackedWeights| -> Vec<u64> {
            forward_packed(cfg, pw, &toks, 1, toks.len(), &ForwardOpts::default())
                .logits
                .data
                .iter()
                .map(|v| v.to_bits())
                .collect()
        };
        Some((bits(&dequant), bits(&coded)))
    }));
    match outcome {
        Err(_) => panic!("{what}: corruption caused a panic"),
        Ok(Some((d, c))) => assert_eq!(
            d, c,
            "{what}: residency modes silently diverged on corrupted bytes"
        ),
        Ok(None) => {} // rejected cleanly somewhere in the pipeline
    }
}

#[test]
fn truncated_container_never_panics_either_residency() {
    let _serial = env_lock();
    let (cfg, teacher, container) = setup();
    let bytes = container.to_bytes();
    for cut in 0..bytes.len() {
        check_corrupted(cfg, teacher, &bytes[..cut], &format!("truncate at {cut}"));
    }
}

#[test]
fn bit_flipped_container_errors_cleanly_or_serves_identically() {
    let _serial = env_lock();
    let (cfg, teacher, container) = setup();
    let bytes = container.to_bytes();
    // one flipped bit per byte position, rotating through the bit
    // lanes so headers, varints, scales, and the rANS code plane all
    // see low- and high-bit damage across the sweep
    for pos in 0..bytes.len() {
        let mut dam = bytes.clone();
        dam[pos] ^= 1u8 << (pos % 8);
        check_corrupted(cfg, teacher, &dam, &format!("flip bit {} of byte {pos}", pos % 8));
    }
}
