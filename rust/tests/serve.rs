//! Serve parity: the micro-batching server's outputs must be
//! **bit-identical** to a sequential per-request dequant-forward
//! reference — invariant across batch sizes, request arrival orders,
//! and `WATERSIC_THREADS`.  This binary mutates that env var, so it
//! lives outside the shared test harness and every test takes
//! [`env_lock`] for its whole body: `setenv` racing the kernels'
//! `getenv` reads would be UB, so no two tests here may overlap.
//!
//! The synthetic tiny model is quantized once (the same deterministic
//! setup the CLI `--model tiny` path and CI's determinism gate use)
//! and the container round-trips through bytes before serving.

use std::sync::OnceLock;
use std::time::Duration;

use watersic::coordinator::container::Container;
use watersic::coordinator::quantize_model;
use watersic::experiments::{synthetic_tiny_opts, synthetic_tiny_setup};
use watersic::linalg::gemm::Precision;
use watersic::model::transformer::{
    decode_packed, forward, forward_packed, greedy_continuation,
    greedy_continuation_rescore, prefill_packed, ForwardOpts, KvCache,
};
use watersic::model::weights::{PackedWeights, Weights};
use watersic::model::ModelConfig;
use watersic::runtime::server::{ScoreHandle, Server};
use watersic::runtime::ServeOpts;
use watersic::util::rng::Rng;
use watersic::util::sync::{classes, TrackedMutex, TrackedMutexGuard};

/// `ServeOpts` with deterministic scheduler limits (env-independent).
fn opts(batch_max: usize, flush: Duration) -> ServeOpts {
    ServeOpts {
        batch_max,
        flush,
        kv_budget: 1 << 30,
        max_steps: 256,
        queue_max: 64,
        deadline: None,
    }
}

/// Serializes every test in this binary: one of them mutates
/// `WATERSIC_THREADS` while the kernels read it through `env::var` on
/// every GEMM call, and a concurrent `setenv`/`getenv` pair is UB on
/// glibc — so no two tests here may overlap.  (Held across the whole
/// test body; the tracked wrapper's poison policy keeps a panicked
/// holder from wedging the rest.)  Ranked `test.env` (rank 0): under
/// `check-locks` this must be the outermost lock a test thread holds,
/// which is exactly the intended nesting — every server/pool lock the
/// body takes ranks strictly higher.
fn env_lock() -> TrackedMutexGuard<'static, ()> {
    static LOCK: TrackedMutex<()> = TrackedMutex::new(&classes::TEST_ENV, ());
    LOCK.lock()
}

/// Quantize the synthetic tiny model once per process.
fn setup() -> &'static (ModelConfig, Weights, Container) {
    static SETUP: OnceLock<(ModelConfig, Weights, Container)> = OnceLock::new();
    SETUP.get_or_init(|| {
        let (cfg, teacher, corpus) = synthetic_tiny_setup();
        let opts = synthetic_tiny_opts(3.0);
        let qm = quantize_model(&cfg, &teacher, &corpus, &opts, None).unwrap();
        let container = Container::new(&cfg.name, qm.quants.clone());
        // round-trip through the wire format, as the CLI load path does
        let container = Container::from_bytes(&container.to_bytes()).unwrap();
        (cfg, teacher, container)
    })
}

/// Deterministic request windows with a spread of lengths.
fn requests(cfg: &ModelConfig, n: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let len = 4 + (i % (cfg.ctx - 3));
            (0..len).map(|_| rng.below(cfg.vocab) as i32).collect()
        })
        .collect()
}

/// Dequantized student weights (the plain-forward reference model).
fn student(teacher: &Weights, container: &Container) -> Weights {
    let mut s = teacher.clone();
    for (name, q) in &container.quants {
        s.set(name, q.dequant());
    }
    s
}

#[test]
fn batched_serve_bit_identical_to_sequential_reference() {
    let _serial = env_lock();
    let (cfg, teacher, container) = setup();
    let prec = Precision::from_env();
    let pw = PackedWeights::from_container(cfg, teacher, container, prec).unwrap();
    let reqs = requests(cfg, 16, 2024);

    // sequential per-request dequant-forward reference: a batch of one
    // through the same prepacked panels
    let reference: Vec<Vec<f64>> = reqs
        .iter()
        .map(|toks| {
            let out =
                forward_packed(cfg, &pw, toks, 1, toks.len(), &ForwardOpts::default());
            out.logits.row(toks.len() - 1).to_vec()
        })
        .collect();

    let run_server = |batch_max: usize, flush_ms: u64, order: &[usize]| {
        let pw =
            PackedWeights::from_container(cfg, teacher, container, prec).unwrap();
        let server =
            Server::start(cfg.clone(), pw, opts(batch_max, Duration::from_millis(flush_ms)));
        let mut handles: Vec<Option<ScoreHandle>> =
            (0..reqs.len()).map(|_| None).collect();
        for &i in order {
            handles[i] = Some(server.submit(reqs[i].clone()).unwrap());
        }
        let outs: Vec<Vec<f64>> = handles
            .into_iter()
            .map(|h| h.unwrap().wait().unwrap().logits_last)
            .collect();
        let stats = server.shutdown();
        assert_eq!(stats.requests, reqs.len());
        if batch_max > 1 {
            assert!(stats.max_batch >= 2, "batching never engaged");
        }
        outs
    };

    let in_order: Vec<usize> = (0..reqs.len()).collect();
    let reversed: Vec<usize> = (0..reqs.len()).rev().collect();
    let batched = run_server(4, 100, &in_order);
    let sequential = run_server(1, 0, &in_order);
    let other_order = run_server(4, 100, &reversed);
    for i in 0..reqs.len() {
        assert_eq!(batched[i], reference[i], "request {i}: batched vs reference");
        assert_eq!(sequential[i], reference[i], "request {i}: batch_max=1");
        assert_eq!(other_order[i], reference[i], "request {i}: arrival order");
    }
}

#[test]
fn serve_outputs_invariant_across_worker_threads() {
    let _serial = env_lock();
    // the tiny quantized model's GEMMs sit below the threads_for()
    // cutoff (they run serial at any WATERSIC_THREADS), which would
    // make this test vacuous — so serve a wider unquantized model
    // whose batched projections clear both the packed and the
    // parallel thresholds and genuinely fan out over the pool
    let cfg = ModelConfig {
        vocab: 64,
        d_model: 64,
        n_heads: 4,
        d_ff: 128,
        ctx: 64,
        ..ModelConfig::tiny_test()
    };
    let weights = Weights::random(&cfg, 77);
    let prec = Precision::from_env();
    // near-full windows: a 3-request batch drives every projection
    // past the 2^18 threads_for() cutoff, so WATERSIC_THREADS=4 really
    // fans the row blocks out
    let mut rng = Rng::new(7);
    let reqs: Vec<Vec<i32>> = (0..6)
        .map(|i| {
            (0..cfg.ctx - (i % 4))
                .map(|_| rng.below(cfg.vocab) as i32)
                .collect()
        })
        .collect();
    let run = || -> Vec<Vec<f64>> {
        let pw = PackedWeights::new(&cfg, weights.clone(), prec);
        let server = Server::start(cfg.clone(), pw, opts(3, Duration::from_millis(50)));
        let handles: Vec<ScoreHandle> = reqs
            .iter()
            .map(|r| server.submit(r.clone()).unwrap())
            .collect();
        handles
            .into_iter()
            .map(|h| h.wait().unwrap().logits_last)
            .collect()
    };
    let old = watersic::util::env::string("WATERSIC_THREADS");
    std::env::set_var("WATERSIC_THREADS", "1");
    let single = run();
    std::env::set_var("WATERSIC_THREADS", "4");
    let multi = run();
    match old {
        Some(v) => std::env::set_var("WATERSIC_THREADS", v),
        None => std::env::remove_var("WATERSIC_THREADS"),
    }
    assert_eq!(single, multi, "serve outputs must not depend on threads");
}

#[test]
fn serve_matches_plain_dequant_forward() {
    let _serial = env_lock();
    let (cfg, teacher, container) = setup();
    let prec = Precision::from_env();
    let student = student(teacher, container);
    let reqs = requests(cfg, 8, 33);
    let pw = PackedWeights::from_container(cfg, teacher, container, prec).unwrap();
    let server = Server::start(cfg.clone(), pw, opts(4, Duration::from_millis(50)));
    let handles: Vec<ScoreHandle> = reqs
        .iter()
        .map(|r| server.submit(r.clone()).unwrap())
        .collect();
    let outs: Vec<Vec<f64>> = handles
        .into_iter()
        .map(|h| h.wait().unwrap().logits_last)
        .collect();
    for (i, toks) in reqs.iter().enumerate() {
        let plain =
            forward(cfg, &student, toks, 1, toks.len(), &ForwardOpts::default());
        let last = plain.logits.row(toks.len() - 1);
        if prec == Precision::F64 {
            // every tiny-model projection either reduces in the same
            // order as the packed tile (k ≤ KC) or runs the very same
            // driver, so the comparison is bitwise
            assert_eq!(outs[i].as_slice(), last, "request {i}");
        } else {
            let norm = last.iter().map(|v| v * v).sum::<f64>().sqrt();
            let diff = outs[i]
                .iter()
                .zip(last)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            assert!(
                diff / norm.max(1e-30) < 1e-3,
                "request {i}: f32 serve drifted ({})",
                diff / norm.max(1e-30)
            );
        }
    }
}

#[test]
fn serve_generate_matches_plain_greedy() {
    let _serial = env_lock();
    let (cfg, teacher, container) = setup();
    if Precision::from_env() != Precision::F64 {
        // an f32 pack can legitimately flip near-tie argmaxes
        return;
    }
    let student = student(teacher, container);
    let pw =
        PackedWeights::from_container(cfg, teacher, container, Precision::F64)
            .unwrap();
    let server = Server::start(cfg.clone(), pw, ServeOpts::default());
    let prompt = [3, 1, 4, 1];
    let toks = server.generate(&prompt, 5).unwrap();
    let expect = greedy_continuation(cfg, &student, &prompt, 5);
    assert_eq!(toks, expect, "batched greedy must match the plain oracle");
}

#[test]
fn serve_decode_matches_rescore_oracle_across_mixes() {
    let _serial = env_lock();
    let (cfg, teacher, container) = setup();
    if Precision::from_env() != Precision::F64 {
        // token-sequence parity is an argmax comparison; an f32 pack
        // can legitimately flip near-tie argmaxes (the logits-level
        // f32 tolerance is pinned below)
        return;
    }
    let student = student(teacher, container);
    // the pinned oracle: the PR 5 loop that re-scores the full window
    // every step — the cached/batched/packed path must reproduce it
    // bit-for-bit, including past ctx where the window slides
    let gens: Vec<(Vec<i32>, usize)> = vec![
        (vec![3, 1, 4, 1, 5, 9, 2, 6], 10), // crosses ctx = 12 mid-run
        (vec![2, 7, 1, 8], 3),
        (vec![1; 12], 6), // saturated from the start: reslide every step
    ];
    let expect: Vec<Vec<i32>> = gens
        .iter()
        .map(|(p, s)| greedy_continuation_rescore(cfg, &student, p, *s))
        .collect();
    let scores = requests(cfg, 4, 88);
    let score_ref: Vec<Vec<f64>> = scores
        .iter()
        .map(|toks| {
            let pw = PackedWeights::from_container(cfg, teacher, container, Precision::F64)
                .unwrap();
            let out =
                forward_packed(cfg, &pw, toks, 1, toks.len(), &ForwardOpts::default());
            out.logits.row(toks.len() - 1).to_vec()
        })
        .collect();

    // mixed interleaved submission: generations and scores share
    // iterations; then batch_max = 1 (every sequence alone)
    for batch_max in [4usize, 1] {
        let pw = PackedWeights::from_container(cfg, teacher, container, Precision::F64)
            .unwrap();
        let server =
            Server::start(cfg.clone(), pw, opts(batch_max, Duration::from_millis(100)));
        let g0 = server.submit_generate(gens[0].0.clone(), gens[0].1).unwrap();
        let s0 = server.submit(scores[0].clone()).unwrap();
        let g1 = server.submit_generate(gens[1].0.clone(), gens[1].1).unwrap();
        let s1 = server.submit(scores[1].clone()).unwrap();
        let g2 = server.submit_generate(gens[2].0.clone(), gens[2].1).unwrap();
        let s2 = server.submit(scores[2].clone()).unwrap();
        let s3 = server.submit(scores[3].clone()).unwrap();
        for (i, (h, want)) in [(g0, &expect[0]), (g1, &expect[1]), (g2, &expect[2])]
            .into_iter()
            .enumerate()
        {
            let out = h.wait().unwrap();
            assert_eq!(
                &out.tokens, want,
                "gen {i} (batch_max {batch_max}) diverged from the rescore oracle"
            );
        }
        for (i, (h, want)) in [(s0, 0), (s1, 1), (s2, 2), (s3, 3)]
            .into_iter()
            .map(|(h, i)| (i, (h, &score_ref[i])))
        {
            assert_eq!(
                &h.wait().unwrap().logits_last,
                want,
                "score {i} (batch_max {batch_max}) drifted while co-batched with decodes"
            );
        }
    }
}

#[test]
fn short_score_completes_while_long_generation_in_flight() {
    let _serial = env_lock();
    let (cfg, teacher, container) = setup();
    let prec = Precision::from_env();
    let pw = PackedWeights::from_container(cfg, teacher, container, prec).unwrap();
    // a long flush window guarantees the generation and the score are
    // admitted into the same first scheduler iteration
    let server = Server::start(cfg.clone(), pw, opts(4, Duration::from_millis(300)));
    let steps = 8;
    let gen = server.submit_generate(vec![5, 6, 7, 8], steps).unwrap();
    let score = server.submit(vec![1, 2, 3]).unwrap();
    let s = score.wait().unwrap();
    let g = gen.wait().unwrap();
    // both joined the same batch...
    assert_eq!(
        s.iteration, g.start_iteration,
        "score and generation did not share the first iteration"
    );
    // ...the generation advanced exactly one token per iteration...
    assert_eq!(
        g.done_iteration - g.start_iteration + 1,
        steps,
        "generation did not advance one token per scheduler iteration"
    );
    // ...so the score left the batch while the generation was still
    // mid-flight: step-granularity join/leave, not whole-request
    assert!(
        s.iteration < g.done_iteration,
        "score should complete while the generation is in flight"
    );
    assert_eq!(g.tokens.len(), 4 + steps);
    assert!(g.ttft_ms >= 0.0 && g.itl_ms.len() == steps - 1);
}

#[test]
fn kv_budget_admission_is_clean_and_serializes() {
    let _serial = env_lock();
    let (cfg, teacher, container) = setup();
    let prec = Precision::from_env();
    // budget = exactly one 4-prompt/4-step cache (cap = 4 + 4 - 1 = 7)
    let one_seq = KvCache::bytes_for(cfg, 7);
    let pw = PackedWeights::from_container(cfg, teacher, container, prec).unwrap();
    let server = Server::start(
        cfg.clone(),
        pw,
        ServeOpts {
            batch_max: 4,
            flush: Duration::from_millis(200),
            kv_budget: one_seq,
            max_steps: 256,
            queue_max: 64,
            deadline: None,
        },
    );
    // a request whose cache could never fit errors cleanly (no OOM,
    // no wedged queue): steps = 12 needs cap = ctx = 12 > 7
    let err = server
        .generate(&[1, 2, 3, 4], 12)
        .unwrap_err()
        .to_string();
    assert!(err.contains("KV"), "unexpected rejection message: {err}");
    // two identical in-budget generations submitted together: the
    // budget admits one at a time, so the second starts only after
    // the first completes and frees its bytes
    let a = server.submit_generate(vec![9, 8, 7, 6], 4).unwrap();
    let b = server.submit_generate(vec![9, 8, 7, 6], 4).unwrap();
    let a = a.wait().unwrap();
    let b = b.wait().unwrap();
    assert_eq!(a.tokens, b.tokens, "identical requests must agree");
    assert!(
        b.start_iteration > a.done_iteration,
        "budget of one sequence must serialize the two generations \
         (a: {}..{}, b: {}..{})",
        a.start_iteration,
        a.done_iteration,
        b.start_iteration,
        b.done_iteration
    );
    // scores ride along regardless of the KV budget
    assert!(server.score(vec![1, 2, 3]).is_ok());
}

#[test]
fn decode_logits_match_full_forward_every_step_across_threads() {
    let _serial = env_lock();
    // wide enough that the projections clear the parallel cutoffs and
    // WATERSIC_THREADS genuinely fans out (see the invariance test)
    let cfg = ModelConfig {
        vocab: 64,
        d_model: 64,
        n_heads: 4,
        d_ff: 128,
        ctx: 64,
        ..ModelConfig::tiny_test()
    };
    let weights = Weights::random(&cfg, 41);
    let prec = Precision::from_env();
    let mut rng = Rng::new(13);
    let toks: Vec<i32> = (0..48).map(|_| rng.below(cfg.vocab) as i32).collect();
    let prefill_len = 40;
    // decode logits at every step, plus the full-forward reference row
    let run = || -> Vec<(Vec<f64>, Vec<f64>)> {
        let pw = PackedWeights::new(&cfg, weights.clone(), prec);
        let mut cache = KvCache::new(&cfg, cfg.ctx);
        {
            let mut kv = [Some((&mut cache, prefill_len))];
            prefill_packed(
                &cfg,
                &pw,
                &toks[..prefill_len],
                1,
                prefill_len,
                &mut kv,
                &ForwardOpts::default(),
            );
        }
        (0..8)
            .map(|i| {
                let t = prefill_len + i + 1;
                let mut caches = [&mut cache];
                let dec = decode_packed(&cfg, &pw, &[toks[t - 1]], &mut caches);
                let full =
                    forward_packed(&cfg, &pw, &toks[..t], 1, t, &ForwardOpts::default());
                (dec.row(0).to_vec(), full.logits.row(t - 1).to_vec())
            })
            .collect()
    };
    let old = watersic::util::env::string("WATERSIC_THREADS");
    std::env::set_var("WATERSIC_THREADS", "1");
    let single = run();
    std::env::set_var("WATERSIC_THREADS", "4");
    let multi = run();
    match old {
        Some(v) => std::env::set_var("WATERSIC_THREADS", v),
        None => std::env::remove_var("WATERSIC_THREADS"),
    }
    assert_eq!(single, multi, "decode bits must not depend on threads");
    for (i, (dec, full)) in single.iter().enumerate() {
        if prec == Precision::F64 {
            // the decode step reproduces the full forward's last row
            // reduction-for-reduction, so the comparison is bitwise
            assert_eq!(dec, full, "step {i}: cached decode vs full forward");
        } else {
            let norm = full.iter().map(|v| v * v).sum::<f64>().sqrt();
            let diff = dec
                .iter()
                .zip(full)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            assert!(
                diff / norm.max(1e-30) < 1e-3,
                "step {i}: f32 decode drifted ({})",
                diff / norm.max(1e-30)
            );
        }
    }
}
