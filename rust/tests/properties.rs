//! Randomized property sweeps over the core invariants (proptest-style,
//! driven by the in-repo RNG so failures reproduce from the printed
//! seed).  These complement the per-module unit tests with cross-module
//! invariants at many random operating points.

use watersic::entropy::huffman::Huffman;
use watersic::entropy::rans::Rans;
use watersic::entropy::{column_coded_rate, entropy_bits, Codec};
use watersic::linalg::chol::cholesky;
use watersic::linalg::gemm::{gram, matmul};
use watersic::linalg::Mat;
use watersic::quant::rate_control::RateBudget;
use watersic::quant::waterfilling::{d_wf, r_wf, spectrum};
use watersic::quant::zsic::{watersic_alphas, zsic};
use watersic::util::rng::Rng;

fn random_spd(n: usize, rng: &mut Rng) -> Mat {
    let samples = Mat::from_fn(2 * n, n, |_, _| rng.gaussian());
    let mut s = gram(&samples).scale(1.0 / (2 * n) as f64);
    s.add_diag(0.02 + 0.2 * rng.uniform());
    s
}

#[test]
fn lemma_3_2_sweep() {
    // e_SIC ∈ CUBE·A·diag(L) for 40 random (W, Σ, c) draws
    for trial in 0..40u64 {
        let mut rng = Rng::new(1000 + trial);
        let a = 4 + rng.below(24);
        let n = 4 + rng.below(28);
        let sigma = random_spd(n, &mut rng);
        let l = cholesky(&sigma).unwrap();
        let w = Mat::from_fn(a, n, |_, _| rng.gaussian() * (0.2 + rng.uniform()));
        let y = matmul(&w, &l);
        let c = 0.05 + rng.uniform();
        let alphas = watersic_alphas(&l, c);
        let out = zsic(&y, &l, &alphas, false, None);
        for i in 0..a {
            for j in 0..n {
                let bound = 0.5 * alphas[j] * l[(j, j)].abs() + 1e-9;
                assert!(
                    out.resid[(i, j)].abs() <= bound,
                    "trial {trial} ({i},{j})"
                );
            }
        }
    }
}

#[test]
fn codec_roundtrip_sweep() {
    // adversarial-ish distributions: heavy skew, wide alphabets, runs
    for trial in 0..25u64 {
        let mut rng = Rng::new(2000 + trial);
        let len = 100 + rng.below(20_000);
        let mode = trial % 5;
        let z: Vec<i32> = (0..len)
            .map(|i| match mode {
                0 => (rng.gaussian() * 3.0) as i32,
                1 => {
                    if rng.uniform() < 0.98 {
                        0
                    } else {
                        rng.below(1000) as i32 - 500
                    }
                }
                2 => (i % 7) as i32 - 3, // periodic
                3 => rng.below(2) as i32, // binary
                _ => (rng.gaussian() * 200.0) as i32, // wide
            })
            .collect();
        for codec in [&Huffman as &dyn Codec, &Rans] {
            let enc = codec.encode(&z);
            let dec = codec.decode(&enc, z.len()).unwrap();
            assert_eq!(dec, z, "trial {trial} codec {}", codec.name());
        }
    }
}

#[test]
fn coded_rate_dominates_entropy_lower_bound() {
    // achieved codec rates must be ≥ joint empirical entropy − ε and
    // within a modest overhead of it at realistic sizes
    let mut rng = Rng::new(7);
    let z: Vec<i32> = (0..60_000)
        .map(|_| (rng.gaussian() * 2.5).round_ties_even() as i32)
        .collect();
    let h = entropy_bits(&z);
    for codec in [&Huffman as &dyn Codec, &Rans] {
        let r = codec.rate(&z);
        assert!(r >= h - 1e-6, "{}: {r} < entropy {h}", codec.name());
        assert!(r <= h + 0.2, "{}: {r} ≫ entropy {h}", codec.name());
    }
}

#[test]
fn per_column_rate_consistency() {
    // per-column coded rate ≤ joint entropy + correction, and both agree
    // for iid columns at large a
    let mut rng = Rng::new(8);
    let (a, n) = (4096usize, 16usize);
    let z: Vec<i32> = (0..a * n)
        .map(|_| (rng.gaussian() * 2.0).round_ties_even() as i32)
        .collect();
    let joint = entropy_bits(&z);
    let per_col = column_coded_rate(&z, a, n);
    assert!(
        (joint - per_col).abs() < 0.03,
        "iid columns at a=4096: joint {joint} vs per-col {per_col}"
    );
}

#[test]
fn waterfilling_rd_curve_properties() {
    // R(D) decreasing and convex-ish in D; d_wf inverse of r_wf
    for trial in 0..10u64 {
        let mut rng = Rng::new(3000 + trial);
        let sigma = random_spd(12 + rng.below(20), &mut rng);
        let lam = spectrum(&sigma);
        let dmax: f64 = lam.iter().sum::<f64>() / lam.len() as f64;
        let mut prev = f64::INFINITY;
        for k in 1..10 {
            let d = dmax * k as f64 / 10.0;
            let r = r_wf(d, &lam, 1.0);
            assert!(r <= prev + 1e-9, "R(D) must be non-increasing");
            assert!(r >= 0.0);
            prev = r;
            // inverse consistency where the curve is strictly decreasing
            if r > 1e-6 {
                let d2 = d_wf(r, &lam, 1.0);
                assert!((d2 - d).abs() < 1e-3 * dmax, "trial {trial}: {d} vs {d2}");
            }
        }
    }
}

#[test]
fn budget_conserves_bits() {
    for trial in 0..20u64 {
        let mut rng = Rng::new(4000 + trial);
        let layers: Vec<usize> = (0..5 + rng.below(10))
            .map(|_| 1000 + rng.below(100_000))
            .collect();
        let total: usize = layers.iter().sum();
        let target = 0.5 + 4.0 * rng.uniform();
        let mut budget = RateBudget::new(target, total);
        for &params in &layers {
            let assigned = budget.assign(params);
            // achieved rate wiggles around the assignment
            let achieved = (assigned + 0.2 * (rng.uniform() - 0.5)).max(0.05);
            budget.charge(params, achieved);
        }
        assert!(budget.done());
        let avg = budget.spent_average(total);
        assert!(
            (avg - target).abs() < 0.15,
            "trial {trial}: avg {avg} vs target {target}"
        );
    }
}

#[test]
fn dequant_scale_invariance() {
    // moving scale between t and γ leaves Ŵ unchanged (the Alg. 4
    // normalization relies on this)
    let mut rng = Rng::new(5);
    let (a, n) = (12usize, 9usize);
    let q = watersic::quant::LayerQuant {
        a,
        n,
        z: (0..a * n).map(|_| rng.below(9) as i32 - 4).collect(),
        alphas: (0..n).map(|_| 0.1 + rng.uniform()).collect(),
        gammas: (0..n).map(|_| 0.5 + rng.uniform()).collect(),
        t: (0..a).map(|_| 0.5 + rng.uniform()).collect(),
        entropy_bits: 0.0,
        rate_bits: 0.0,
        dead_cols: vec![],
    };
    let w1 = q.dequant();
    let s = 2.7;
    let mut q2 = q;
    q2.t.iter_mut().for_each(|t| *t /= s);
    q2.gammas.iter_mut().for_each(|g| *g *= s);
    let w2 = q2.dequant();
    assert!(w1.sub(&w2).max_abs() < 1e-12);
}

#[test]
fn zsic_distortion_monotone_in_density() {
    // finer lattices (smaller c) never increase distortion — 12 draws
    for trial in 0..12u64 {
        let mut rng = Rng::new(6000 + trial);
        let (a, n) = (24 + rng.below(40), 8 + rng.below(24));
        let sigma = random_spd(n, &mut rng);
        let l = cholesky(&sigma).unwrap();
        let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
        let y = matmul(&w, &l);
        let d_at = |c: f64| {
            let out = zsic(&y, &l, &watersic_alphas(&l, c), false, None);
            out.resid.data.iter().map(|x| x * x).sum::<f64>()
        };
        let coarse = d_at(0.9);
        let fine = d_at(0.15);
        assert!(fine < coarse, "trial {trial}: {fine} !< {coarse}");
    }
}

#[test]
fn packed_kernels_deterministic_across_thread_counts() {
    // the packed gemm/gram tile decomposition and K order are fixed, so
    // results must be bit-for-bit identical whatever the thread count
    // (the WATERSIC_THREADS=1 vs threaded contract)
    use watersic::linalg::gemm::{gram_with_threads, matmul_with_threads};
    let mut rng = Rng::new(7777);
    let a = Mat::from_fn(180, 140, |_, _| rng.gaussian());
    let b = Mat::from_fn(140, 160, |_, _| rng.gaussian());
    let c1 = matmul_with_threads(&a, &b, 1);
    for t in [2usize, 3, 8] {
        let ct = matmul_with_threads(&a, &b, t);
        assert!(c1.sub(&ct).max_abs() <= 1e-9, "threads={t}");
        assert_eq!(c1.data, ct.data, "threads={t}: not bit-identical");
    }
    let g1 = gram_with_threads(&a, 1);
    for t in [2usize, 8] {
        assert_eq!(
            g1.data,
            gram_with_threads(&a, t).data,
            "gram threads={t}: not bit-identical"
        );
    }
}

#[test]
fn zsic_packed_deferred_update_keeps_invariants() {
    // n > 64 activates the packed rank-B deferred panel update inside
    // zsic; the reconstruction identity and the Lemma 3.2 cube bound
    // must survive the kernel swap
    let mut rng = Rng::new(4242);
    let (a, n) = (48usize, 160usize);
    let sigma = random_spd(n, &mut rng);
    let l = cholesky(&sigma).unwrap();
    let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
    let y = matmul(&w, &l);
    let alphas = watersic_alphas(&l, 0.4);
    // lmmse off: the cube bound below is a property of the plain
    // quantizer (γ ≡ 1); the reconstruction identity holds either way
    let out = zsic(&y, &l, &alphas, false, None);
    // Y − Z·diag(γα)·L == resid
    let mut zm = Mat::zeros(a, n);
    for r in 0..a {
        for j in 0..n {
            zm[(r, j)] = out.z[r * n + j] as f64 * out.gammas[j] * alphas[j];
        }
    }
    let recon = matmul(&zm, &l);
    let diff = y.sub(&recon).sub(&out.resid);
    assert!(diff.max_abs() < 1e-9, "reconstruction drift {}", diff.max_abs());
    // e_SIC ∈ CUBE·A·diag(L)
    for i in 0..a {
        for j in 0..n {
            let bound = 0.5 * alphas[j] * l[(j, j)].abs() + 1e-9;
            assert!(
                out.resid[(i, j)].abs() <= bound,
                "({i},{j}): {} > {bound}",
                out.resid[(i, j)].abs()
            );
        }
    }
}
