#![cfg(feature = "fault-inject")]
//! Deterministic fault-injection sweeps over the live front door
//! (`cargo test --features fault-inject --test fault`): partial and
//! delayed reads, injected mid-request disconnects, write stalls, and a
//! scheduler panic during batched work.  Under every fault the server
//! must stay up, answer unaffected clients **bit-identically** to a
//! fault-free reference, and emit only well-formed JSON errors.
//!
//! Own binary: [`install`] swaps a process-global fault plan, so every
//! test serializes on [`fault_lock`] and computes its fault-free
//! references *before* installing its plan (installation resets the
//! per-site hit counters, keeping each schedule deterministic).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use watersic::experiments::synthetic_tiny_setup;
use watersic::linalg::gemm::Precision;
use watersic::model::weights::PackedWeights;
use watersic::runtime::reactor::{self, ReactorOpts};
use watersic::runtime::{ServeOpts, Server};
use watersic::util::fault::{install, Plan};
use watersic::util::json::Json;
use watersic::util::sync::{classes, TrackedMutex, TrackedMutexGuard};

/// The fault plan is process-global state: no two tests may overlap.
/// Ranked `test.env` (rank 0) so under `check-locks` it must be the
/// outermost lock any test thread holds.
fn fault_lock() -> TrackedMutexGuard<'static, ()> {
    static LOCK: TrackedMutex<()> = TrackedMutex::new(&classes::TEST_ENV, ());
    LOCK.lock()
}

fn plan(spec: &str) -> Option<Plan> {
    Some(Plan::parse(spec).unwrap())
}

fn opts() -> ServeOpts {
    ServeOpts {
        batch_max: 4,
        flush: Duration::from_micros(0),
        kv_budget: 1 << 30,
        max_steps: 1 << 20,
        queue_max: 64,
        deadline: None,
    }
}

fn tiny_server() -> Arc<Server> {
    let (cfg, teacher, _) = synthetic_tiny_setup();
    let packed = PackedWeights::new(&cfg, teacher, Precision::from_env());
    Arc::new(Server::start(cfg, packed, opts()))
}

fn ropts() -> ReactorOpts {
    ReactorOpts {
        max_conns: 16,
        idle: Duration::from_secs(10),
        write_stall: Duration::from_secs(10),
    }
}

/// Run the reactor front door, hand the body its address, then stop,
/// clear the fault plan, and assert the front door exited cleanly.
fn with_front_door<F: FnOnce(SocketAddr, &Server)>(server: &Arc<Server>, body: F) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let ropts = ropts();
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        let door = s.spawn(|| reactor::serve(server, &listener, &ropts, &stop));
        body(addr, server);
        install(None);
        stop.store(true, Ordering::Relaxed);
        door.join().unwrap().unwrap();
    });
}

fn connect(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn send_line(stream: &mut TcpStream, line: &str) {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
}

/// Read one response line and parse it; panics on EOF.
fn read_json(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    let n = reader.read_line(&mut line).unwrap();
    assert!(n > 0, "connection closed before a response arrived");
    Json::parse(line.trim()).unwrap()
}

/// `true` iff the peer closed the connection with no (further) data.
fn at_eof(reader: &mut BufReader<TcpStream>) -> bool {
    let mut line = String::new();
    matches!(reader.read_line(&mut line), Ok(0))
}

/// Fault-free reference for a score request, via direct submission on
/// the same server the faulty TCP path will hit.
fn score_ref(server: &Server, toks: &[i32]) -> (usize, usize, f64) {
    let out = server.submit(toks.to_vec()).unwrap().wait().unwrap();
    (out.len, out.argmax(), out.nll)
}

/// Assert a TCP score response matches the reference **exactly** —
/// `nll` is serialized with Rust's shortest-round-trip float display,
/// so bit-identical outputs survive the protocol.
fn assert_matches_ref(j: &Json, reference: (usize, usize, f64)) {
    assert!(j.get("error").is_none(), "errored: {}", j.to_string_compact());
    assert_eq!(j.req("len").unwrap().as_usize().unwrap(), reference.0);
    assert_eq!(j.req("next").unwrap().as_f64().unwrap(), reference.1 as f64);
    assert_eq!(j.req("nll").unwrap().as_f64().unwrap(), reference.2);
}

const REQ_A: &str = "{\"tokens\": [1, 2, 3, 4, 5]}";
const REQ_B: &str = "{\"tokens\": [9, 8, 7]}";
const TOKS_A: &[i32] = &[1, 2, 3, 4, 5];
const TOKS_B: &[i32] = &[9, 8, 7];

#[test]
fn partial_reads_trickle_requests_through_intact() {
    let _serial = fault_lock();
    let server = tiny_server();
    with_front_door(&server, |addr, srv| {
        let ra = score_ref(srv, TOKS_A);
        let rb = score_ref(srv, TOKS_B);
        // EVERY read pass delivers at most one byte
        install(plan("read=partial"));
        let (mut c, mut r) = connect(addr);
        send_line(&mut c, REQ_A);
        assert_matches_ref(&read_json(&mut r), ra);
        send_line(&mut c, REQ_B);
        assert_matches_ref(&read_json(&mut r), rb);
    });
}

#[test]
fn slow_reads_delay_but_do_not_corrupt() {
    let _serial = fault_lock();
    let server = tiny_server();
    with_front_door(&server, |addr, srv| {
        let ra = score_ref(srv, TOKS_A);
        install(plan("read=slow:5@e3"));
        let (mut c, mut r) = connect(addr);
        for _ in 0..4 {
            send_line(&mut c, REQ_A);
            assert_matches_ref(&read_json(&mut r), ra);
        }
    });
}

#[test]
fn injected_disconnect_kills_one_conn_not_the_server() {
    let _serial = fault_lock();
    let server = tiny_server();
    with_front_door(&server, |addr, srv| {
        let rb = score_ref(srv, TOKS_B);
        // the FIRST completed request line loses its connection
        install(plan("conn=drop@n1"));
        let (mut a, mut ra) = connect(addr);
        send_line(&mut a, REQ_A);
        assert!(at_eof(&mut ra), "faulted connection must die silently");
        // an unaffected client gets bit-identical service
        let (mut b, mut rbuf) = connect(addr);
        send_line(&mut b, REQ_B);
        assert_matches_ref(&read_json(&mut rbuf), rb);
    });
}

#[test]
fn dropped_connections_at_accept_do_not_wedge_the_listener() {
    let _serial = fault_lock();
    let server = tiny_server();
    with_front_door(&server, |addr, srv| {
        let ra = score_ref(srv, TOKS_A);
        // the first accepted connection is dropped on the floor
        install(plan("accept=drop@n1"));
        let (_dead, mut rdead) = connect(addr);
        assert!(at_eof(&mut rdead), "sacrificial connection must close");
        let (mut c, mut r) = connect(addr);
        send_line(&mut c, REQ_A);
        assert_matches_ref(&read_json(&mut r), ra);
    });
}

#[test]
fn write_stalls_delay_responses_without_losing_them() {
    let _serial = fault_lock();
    let server = tiny_server();
    with_front_door(&server, |addr, srv| {
        let ra = score_ref(srv, TOKS_A);
        let rb = score_ref(srv, TOKS_B);
        // every second flush stalls 50 ms — well under the write-stall
        // timeout, so responses arrive late but intact and in order
        install(plan("write=stall:50@e2"));
        let (mut c, mut r) = connect(addr);
        c.write_all(REQ_A.as_bytes()).unwrap();
        c.write_all(b"\n").unwrap();
        c.write_all(REQ_B.as_bytes()).unwrap();
        c.write_all(b"\n").unwrap();
        assert_matches_ref(&read_json(&mut r), ra);
        assert_matches_ref(&read_json(&mut r), rb);
    });
}

#[test]
fn injected_lock_delays_are_bit_transparent() {
    let _serial = fault_lock();
    let server = tiny_server();
    with_front_door(&server, |addr, srv| {
        let ra = score_ref(srv, TOKS_A);
        let rb = score_ref(srv, TOKS_B);
        // every 7th tracked-lock acquisition anywhere in the process
        // (queue, condvar reacquires, pool, fault state itself) sleeps
        // 1 ms — widened race windows must not change a single bit
        install(plan("lock=slow:1@e7"));
        let (mut c, mut r) = connect(addr);
        for _ in 0..3 {
            send_line(&mut c, REQ_A);
            assert_matches_ref(&read_json(&mut r), ra);
            send_line(&mut c, REQ_B);
            assert_matches_ref(&read_json(&mut r), rb);
        }
    });
}

#[test]
fn scheduler_panic_is_contained_to_its_iteration() {
    let _serial = fault_lock();
    let server = tiny_server();
    with_front_door(&server, |addr, srv| {
        let ra = score_ref(srv, TOKS_A);
        let rb = score_ref(srv, TOKS_B);
        // the SECOND worked scheduler iteration panics mid-decode path;
        // the batcher's catch_unwind must contain it
        install(plan("sched=panic@n2"));
        let (mut c, mut r) = connect(addr);
        // iteration 1: fine
        send_line(&mut c, REQ_A);
        assert_matches_ref(&read_json(&mut r), ra);
        // iteration 2: its batch dies, but as a well-formed JSON error
        send_line(&mut c, REQ_B);
        let j = read_json(&mut r);
        assert!(j.get("error").is_some(), "expected an error response");
        assert!(!j.req("error").unwrap().as_str().unwrap().is_empty());
        // iteration 3: the server recovered, bit-identical service
        send_line(&mut c, REQ_B);
        assert_matches_ref(&read_json(&mut r), rb);
        assert!(srv.stats().requests >= 3);
    });
}
