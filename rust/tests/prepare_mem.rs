//! Regression test for the streaming bounded prepare: a counting
//! global allocator bounds the peak transient footprint of the
//! pipeline's quantization front-end.  Before the fix, the coordinator
//! materialized all 7 `PreparedLayer` pairs of a transformer layer in
//! parallel before the sequential budget loop drained them (~7× the
//! front-end footprint, with every pair also holding its own copy of
//! the live-restricted covariances and Cholesky factor); after it, a
//! producer/consumer with a bounded lookahead window holds at most
//! `prepare_lookahead` prepared front-ends alive, each sharing one
//! `PreparedStats` between its full and subsample systems.
//!
//! The same single-test binary also pins the one-factorization-per-
//! layer invariant through the *process-global* counter — the
//! streaming producer factors on its own thread, which the
//! thread-local counter cannot see.  (Own test binary — see
//! Cargo.toml — so the allocator instrumentation and the global
//! counter cannot race unrelated tests.)

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use watersic::calib::corpus::{batch_windows, Corpus};
use watersic::calib::drift::CalibSet;
use watersic::coordinator::{quantize_model, PipelineOpts};
use watersic::linalg::chol::factorization_count_global;
use watersic::model::weights::Weights;
use watersic::model::ModelConfig;
use watersic::quant::watersic::{layer_seed_from_name, prepare_at_rate};
use watersic::quant::LayerStats;

struct CountingAlloc;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

// SAFETY: defers every allocation to `System` and only adds atomic
// counter updates, so the GlobalAlloc contract is System's own.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: contract forwarded verbatim to `System.alloc`.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::SeqCst) + layout.size();
            PEAK.fetch_max(live, Ordering::SeqCst);
        }
        p
    }

    // SAFETY: contract forwarded verbatim to `System.dealloc`.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE.fetch_sub(layout.size(), Ordering::SeqCst);
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn setup() -> (ModelConfig, Weights, Corpus, PipelineOpts) {
    // wide enough that the n×n covariances and a×n targets of the
    // prepared front-ends dominate every other allocation; short
    // context so the calibration forwards stay negligible
    // vocab must cover raw corpus bytes (the tokenizer is byte-level)
    let cfg = ModelConfig {
        vocab: 128,
        d_model: 96,
        n_heads: 2,
        d_ff: 192,
        ctx: 16,
        ..ModelConfig::tiny_test()
    };
    let teacher = Weights::random(&cfg, 11);
    let text: String = (0..400)
        .map(|i| format!("alpha beta {} gamma. ", i % 37))
        .collect();
    let corpus = Corpus::from_bytes("prepare-mem", text.into_bytes());
    let mut opts = PipelineOpts::watersic(3.0);
    opts.calib_windows = 2;
    opts.calib_batch = 1;
    opts.use_engine = false;
    opts.subsample_rows = 24;
    // the Γ-step's transient mats and factorizations are not front-end
    opts.quant.rescalers = false;
    (cfg, teacher, corpus, opts)
}

#[test]
fn streaming_prepare_stays_below_all_at_once_footprint() {
    let (cfg, teacher, corpus, mut opts) = setup();

    // warm up: thread pool, lazily allocated engine state
    opts.prepare_lookahead = 2;
    let _ = quantize_model(&cfg, &teacher, &corpus, &opts, None).unwrap();

    // ---- reference: the all-at-once flow (the pre-streaming
    // coordinator), holding every matrix's drift stats and prepared
    // pair alive simultaneously before the budget loop would drain them
    let windows = corpus.calib_windows(opts.calib_windows, cfg.ctx, opts.seed);
    let batches: Vec<Vec<i32>> = batch_windows(&windows, opts.calib_batch)
        .into_iter()
        .map(|(t, _)| t)
        .collect();
    let cs = CalibSet::build_prec(&cfg, &teacher, batches, opts.calib_batch, opts.precision);
    let scaps = cs.student_pass(&cfg, &teacher);
    let order: Vec<String> = cfg.quantizable.clone();

    let base = LIVE.load(Ordering::SeqCst);
    PEAK.store(base, Ordering::SeqCst);
    let fac_before = factorization_count_global();
    {
        let stats: Vec<LayerStats> = order
            .iter()
            .map(|name| {
                cs.stats_for(
                    &cfg,
                    name,
                    &scaps,
                    watersic::calib::drift::StatsOpts {
                        drift: opts.drift,
                        residual: opts.residual,
                        attn_weighted: opts.attn_weighted,
                    },
                )
            })
            .collect();
        let pairs: Vec<_> = order
            .iter()
            .zip(&stats)
            .map(|(name, st)| {
                prepare_at_rate(
                    teacher.get(name),
                    st,
                    &opts.quant,
                    opts.subsample_rows,
                    layer_seed_from_name(name),
                )
                .unwrap()
            })
            .collect();
        assert_eq!(pairs.len(), 7);
        assert_eq!(
            factorization_count_global() - fac_before,
            7,
            "shared PreparedStats must factor exactly once per matrix"
        );
    }
    let all_at_once_peak = PEAK.load(Ordering::SeqCst).saturating_sub(base);
    drop(scaps);
    drop(cs);

    // ---- streaming pipeline at the tightest window
    opts.prepare_lookahead = 1;
    let base = LIVE.load(Ordering::SeqCst);
    PEAK.store(base, Ordering::SeqCst);
    let fac_before = factorization_count_global();
    let qm = quantize_model(&cfg, &teacher, &corpus, &opts, None).unwrap();
    let streaming_peak = PEAK.load(Ordering::SeqCst).saturating_sub(base);

    assert_eq!(qm.report.matrices.len(), 7);
    assert_eq!(qm.report.prepare_peak_pairs, 1);
    assert_eq!(
        factorization_count_global() - fac_before,
        7,
        "the streaming pipeline must still factor exactly once per matrix"
    );

    // the full pipeline run — weights, codes, calibration and all —
    // must peak below the bare front-end of the all-at-once flow
    assert!(
        streaming_peak * 10 < all_at_once_peak * 9,
        "streaming prepare peaked at {streaming_peak} B vs {all_at_once_peak} B \
         for the all-at-once flow — is the bounded window gone?"
    );
}
