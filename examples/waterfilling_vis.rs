//! Visualize the waterfilling rate allocation (§3.1) and the per-column
//! rates WaterSIC actually realizes — ASCII rendition of Fig. 5's left
//! panel plus the classical waterfilling picture.
//!
//!     cargo run --release --offline --example waterfilling_vis

use watersic::linalg::chol::cholesky;
use watersic::linalg::Mat;
use watersic::quant::waterfilling::{ar1_sigma, d_wf, spectrum};
use watersic::quant::watersic::plain_watersic;
use watersic::util::rng::Rng;

fn bar(x: f64, scale: f64) -> String {
    "█".repeat(((x * scale) as usize).clamp(0, 60))
}

fn main() -> anyhow::Result<()> {
    let n = 32;
    let rho = 0.9;
    let sigma = ar1_sigma(n, rho);
    let lam = spectrum(&sigma);
    let rate = 2.0;
    let d = d_wf(rate, &lam, 1.0);

    println!("Reverse waterfilling at R = {rate} bits (AR(1) ρ = {rho}, n = {n})");
    println!("water level τ chosen so that Σ min(λ_i, τ) = nD, D = {d:.4}\n");
    println!("{:>4} {:>9} {:>7}  per-eigendirection rate", "i", "λ_i", "R_i");
    // recover τ from D: every direction with λ > τ gets ½log(λ/τ)
    let tau = {
        let (mut lo, mut hi) = (1e-12, lam[0]);
        for _ in 0..100 {
            let mid = (lo * hi).sqrt();
            let dm: f64 =
                lam.iter().map(|&l| l.min(mid)).sum::<f64>() / n as f64;
            if dm < d {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        (lo * hi).sqrt()
    };
    for (i, &l) in lam.iter().enumerate().take(16) {
        let ri = if l > tau { 0.5 * (l / tau).log2() } else { 0.0 };
        println!("{:>4} {:>9.4} {:>7.3}  {}", i, l, ri, bar(ri, 12.0));
    }
    println!("   … ({} more)\n", n - 16);

    // What PlainWaterSIC actually does: per-column rates from the
    // Cholesky innovation variances (no PCA rotation needed!).
    let mut rng = Rng::new(3);
    let w = Mat::from_fn(1024, n, |_, _| rng.gaussian());
    let l = cholesky(&sigma)?;
    let gm = watersic::quant::zsic::geomean_diag(&l);
    let q = plain_watersic(&w, &sigma, gm * 2f64.powf(-rate) * 4.13, false)?;
    let ce = q.column_entropies();
    println!("PlainWaterSIC per-column (in-feature) realized rates:");
    for (j, &e) in ce.iter().enumerate().take(16) {
        println!(
            "{:>4} ℓ_jj={:>6.3} {:>6.2} bit  {}",
            j,
            l[(j, j)],
            e,
            bar(e, 10.0)
        );
    }
    println!("   … ({} more)", n - 16);
    println!(
        "\nmean column rate {:.3} bits — unequal allocation tracking the \
         innovation variances ℓ_jj (first columns carry more information).",
        ce.iter().sum::<f64>() / n as f64
    );
    Ok(())
}
