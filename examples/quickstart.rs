//! Quickstart: quantize a single linear layer with WaterSIC and compare
//! against GPTQ and the information-theoretic limit.
//!
//!     cargo run --release --offline --example quickstart

use watersic::linalg::chol::cholesky;
use watersic::linalg::Mat;
use watersic::quant::waterfilling::{ar1_sigma, r_wf, spectrum, SHAPING_GAP_BITS};
use watersic::quant::watersic::plain_watersic;
use watersic::quant::zsic::geomean_diag;
use watersic::quant::{distortion, gptq};
use watersic::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // A synthetic layer: 512 output channels, 96 input features whose
    // activations are strongly correlated (AR(1), ρ = 0.95).
    let (a, n, rho) = (512, 96, 0.95);
    let sigma = ar1_sigma(n, rho);
    let mut rng = Rng::new(1);
    let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
    let lam = spectrum(&sigma);
    let l = cholesky(&sigma)?;
    let gm = geomean_diag(&l);

    println!("layer: {a}×{n}, AR(1) ρ={rho} activations\n");
    println!(
        "{:>6} | {:>10} {:>8} | {:>10} {:>8}",
        "rate", "D(WaterSIC)", "gap", "D(GPTQ)", "gap"
    );
    println!("{}", "-".repeat(52));

    for target in [2.0, 3.0, 4.0] {
        // WaterSIC spacing α_i = c/ℓ_ii; GPTQ spacing A = αI — matched
        // lattice density, rates targeted by secant.
        let q_ws = plain_watersic(&w, &sigma, gm * 2f64.powf(-target) * 4.1, false)?;
        let q_gq = gptq::gptq_at_rate(
            &w,
            &watersic::quant::LayerStats::from_sigma(sigma.clone()),
            q_ws.entropy_bits,
            false,
            0.0,
        )?;
        let d_ws = distortion(&w, &q_ws.dequant(), &sigma);
        let d_gq = distortion(&w, &q_gq.dequant(), &sigma);
        let gap_ws = q_ws.entropy_bits - r_wf(d_ws, &lam, 1.0);
        let gap_gq = q_gq.entropy_bits - r_wf(d_gq, &lam, 1.0);
        println!(
            "{:>6.2} | {:>10.3e} {:>8.3} | {:>10.3e} {:>8.3}",
            q_ws.entropy_bits, d_ws, gap_ws, d_gq, gap_gq
        );
    }
    println!(
        "\nWaterSIC's gap to the IT limit ≈ the lattice shaping constant \
         ({SHAPING_GAP_BITS:.3} bit);\nGPTQ additionally pays the AM/GM \
         spread of the Cholesky diagonal (Thm 3.3)."
    );
    Ok(())
}
