//! Compare the in-repo entropy coders (canonical Huffman, rANS) against
//! real zstd / DEFLATE on actual WaterSIC integer codes — the Table 6
//! story as a standalone example, plus coder throughput.
//!
//!     cargo run --release --offline --example codec_compare

use std::time::Instant;

use watersic::entropy::external::{deflate_bpp, zstd_bpp, ZstdCodec};
use watersic::entropy::huffman::Huffman;
use watersic::entropy::rans::Rans;
use watersic::entropy::{column_coded_rate, entropy_bits, Codec};
use watersic::linalg::Mat;
use watersic::quant::waterfilling::ar1_sigma;
use watersic::quant::watersic::plain_watersic;
use watersic::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // realistic codes: quantize a large Gaussian layer at ~2.1 bits
    let (a, n) = (2048, 128);
    let sigma = ar1_sigma(n, 0.85);
    let mut rng = Rng::new(11);
    let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
    let l = watersic::linalg::chol::cholesky(&sigma)?;
    let gm = watersic::quant::zsic::geomean_diag(&l);
    let q = plain_watersic(&w, &sigma, gm, true)?;
    let z = &q.z;
    println!(
        "codes: {a}×{n}, joint entropy {:.3} bits, per-column coded rate {:.3} bits\n",
        entropy_bits(z),
        column_coded_rate(z, a, n)
    );

    println!(
        "{:<10} {:>9} {:>12} {:>12} {:>10}",
        "codec", "bits/sym", "enc MB/s", "dec MB/s", "lossless"
    );
    println!("{}", "-".repeat(58));
    for codec in [&Huffman as &dyn Codec, &Rans, &ZstdCodec] {
        let t0 = Instant::now();
        let enc = codec.encode(z);
        let t_enc = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let dec = codec.decode(&enc, z.len())?;
        let t_dec = t1.elapsed().as_secs_f64();
        let mb = (z.len() * 4) as f64 / 1e6;
        println!(
            "{:<10} {:>9.3} {:>12.1} {:>12.1} {:>10}",
            codec.name(),
            8.0 * enc.len() as f64 / z.len() as f64,
            mb / t_enc,
            mb / t_dec,
            if dec == *z { "yes" } else { "NO!" }
        );
    }
    // byte-stream general-purpose codecs (paper's Table 6 measurement)
    println!(
        "{:<10} {:>9.3}   (column-major int8 packing, level 22)",
        "zstd-22",
        zstd_bpp(z, a, n)
    );
    println!(
        "{:<10} {:>9.3}   (column-major int8 packing, best)",
        "deflate",
        deflate_bpp(z, a, n)
    );
    println!(
        "\nAll coders land within a few tenths of a bit of the entropy \
         estimate — the paper's premise that entropy ≈ achievable rate."
    );
    Ok(())
}
