//! End-to-end driver (DESIGN.md "End-to-end validation"): loads the
//! build-time-trained picollama_s, quantizes it with the full WaterSIC
//! pipeline at 2 bits/weight through the PJRT ZSIC artifacts, finetunes
//! the rescalers, serializes / reloads the compressed container, and
//! evaluates perplexity, KL and the probe suite on held-out data —
//! proving that all three layers (Pallas kernel → JAX graph → Rust
//! coordinator) compose on a real workload.
//!
//!     make artifacts && cargo run --release --offline --example quantize_llm

use watersic::coordinator::container::Container;
use watersic::coordinator::{quantize_model, Algo};
use watersic::experiments::{llm::pipeline_opts, Ctx};
use watersic::eval;

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new(false, true)?;
    let (cfg, teacher) = ctx.load_model("picollama_s")?;
    let wiki = ctx.load_corpus("wiki")?;
    let web = ctx.load_corpus("web")?;
    println!(
        "model {} ({} params) — BF16 wiki PPL {:.3}",
        cfg.name, cfg.n_params, cfg.bf16_ppl_wiki
    );

    // 1. quantize at 2 bits with the full pipeline + FT
    let mut opts = pipeline_opts(&ctx, Algo::WaterSic, 2.0, true);
    opts.mixing = true;
    opts.mixing_iters = 5;
    let t0 = std::time::Instant::now();
    let qm = quantize_model(&cfg, &teacher, &wiki, &opts, ctx.engine.as_ref())?;
    println!(
        "\nquantized 14 matrices in {:.1}s — avg rate {:.3} bits/weight",
        t0.elapsed().as_secs_f64(),
        qm.report.avg_rate
    );
    let via_pjrt = qm.report.matrices.iter().filter(|m| m.via_artifact).count();
    println!(
        "ZSIC executed via PJRT artifact for {via_pjrt}/{} matrices",
        qm.report.matrices.len()
    );
    if !qm.report.ft_loss_trace.is_empty() {
        println!(
            "FT distillation KL: {:.4} → {:.4} nats over {} steps",
            qm.report.ft_loss_trace[0],
            qm.report.ft_loss_trace.last().unwrap(),
            qm.report.ft_loss_trace.len()
        );
    }

    // 2. container round trip
    let path = std::env::temp_dir().join("picollama_s_2bit.wsic");
    Container::new(&cfg.name, qm.quants.clone()).save(&path)?;
    let container = Container::load(&path)?;
    println!(
        "\ncontainer: {} ({:.1} KiB, {:.2} bits/quantized-weight measured)",
        path.display(),
        container.size_bytes() as f64 / 1024.0,
        8.0 * container.size_bytes() as f64 / cfg.quantizable_params() as f64
    );
    let mut student = teacher.clone();
    for (name, q) in &container.quants {
        student.set(name, q.dequant());
    }

    // 3. evaluation on held-out windows (in-domain + off-domain)
    let wiki_eval = wiki.eval_windows(48, cfg.ctx, 99);
    let web_eval = web.eval_windows(48, cfg.ctx, 99);
    let ppl_wiki = match &ctx.engine {
        Some(e) => eval::perplexity_runtime(e, &cfg, &student, &wiki_eval, 8)?,
        None => eval::perplexity_native(&cfg, &student, &wiki_eval),
    };
    let ppl_web = eval::perplexity_native(&cfg, &student, &web_eval);
    let kl = eval::kl_to_teacher(&cfg, &teacher, &student, &wiki_eval[..12]);
    let probes = eval::probe_suite(&cfg, &student, &wiki_eval);
    println!("\n== results @ {:.2} bits ==", qm.report.avg_rate);
    println!(
        "wiki PPL {ppl_wiki:.3} (BF16 {:.3})   web PPL {ppl_web:.3} (BF16 {:.3})",
        cfg.bf16_ppl_wiki, cfg.bf16_ppl_web
    );
    println!("KL(teacher‖student) {kl:.4} nats/token");
    println!(
        "probes: top1 {:.3} digits {:.3} word-start {:.3} whitespace {:.3}",
        probes.top1, probes.digits, probes.word_start, probes.whitespace
    );
    anyhow::ensure!(ppl_wiki < 8.0, "2-bit model should stay usable");
    println!("\nOK — full three-layer stack validated end to end.");
    Ok(())
}
