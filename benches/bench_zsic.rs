//! ZSIC kernel throughput (L3 hot path): weights/sec across layer
//! shapes, LMMSE on/off, plus the effective GFLOP/s of the rank-1
//! interference updates (the kernel's arithmetic core, ≈ a·n²/2 MACs).

use std::time::Duration;

use watersic::linalg::chol::cholesky;
use watersic::linalg::gemm::matmul;
use watersic::linalg::Mat;
use watersic::quant::waterfilling::ar1_sigma;
use watersic::quant::zsic::{watersic_alphas, zsic};
use watersic::util::bench::{report, Bench};
use watersic::util::rng::Rng;

fn main() {
    println!("== bench_zsic: ZSIC quantizer throughput ==");
    let mut rng = Rng::new(1);
    for (a, n) in [(64usize, 64usize), (256, 64), (512, 128), (1024, 256)] {
        let sigma = ar1_sigma(n, 0.9);
        let l = cholesky(&sigma).unwrap();
        let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
        let y = matmul(&w, &l);
        let alphas = watersic_alphas(&l, 0.3);
        for lmmse in [false, true] {
            let stats = Bench::new(&format!(
                "zsic {a}x{n} lmmse={}",
                if lmmse { "y" } else { "n" }
            ))
            .with_budget(8, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(zsic(&y, &l, &alphas, lmmse, None));
            });
            let weights = (a * n) as f64;
            let macs = a as f64 * n as f64 * n as f64 / 2.0;
            report(&stats, Some((weights, "weights")));
            println!(
                "{:>44}   ({:.2} GMAC/s effective)",
                "",
                macs / stats.per_iter_secs() / 1e9
            );
        }
    }
}
