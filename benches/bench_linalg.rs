//! Dense linear-algebra substrate throughput: gemm / Gram / Cholesky /
//! triangular solve — the flop backbone of calibration and rescaler
//! optimization.

use std::time::Duration;

use watersic::linalg::chol::{cholesky, solve_xlt_eq_b};
use watersic::linalg::gemm::{gram, matmul, matmul_nt};
use watersic::linalg::Mat;
use watersic::util::bench::{report, Bench};
use watersic::util::rng::Rng;

fn main() {
    println!("== bench_linalg: f64 dense kernels ==");
    let mut rng = Rng::new(3);
    for n in [64usize, 128, 256, 512] {
        let a = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let b = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let flops = 2.0 * (n * n * n) as f64;
        let s = Bench::new(&format!("matmul {n}³"))
            .with_budget(6, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(matmul(&a, &b));
            });
        report(&s, Some((flops, "FLOP")));
        let s = Bench::new(&format!("matmul_nt {n}³"))
            .with_budget(6, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(matmul_nt(&a, &b));
            });
        report(&s, Some((flops, "FLOP")));
    }
    for n in [64usize, 128, 256] {
        let panel = Mat::from_fn(2048, n, |_, _| rng.gaussian());
        let s = Bench::new(&format!("gram 2048x{n}"))
            .with_budget(6, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(gram(&panel));
            });
        report(&s, Some((2048.0 * (n * n) as f64, "FLOP")));
        let mut spd = gram(&panel).scale(1.0 / 2048.0);
        spd.add_diag(0.01);
        let s = Bench::new(&format!("cholesky {n}"))
            .with_budget(6, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(cholesky(&spd).unwrap());
            });
        report(&s, Some(((n * n * n) as f64 / 3.0, "FLOP")));
        let l = cholesky(&spd).unwrap();
        let rhs = Mat::from_fn(256, n, |_, _| rng.gaussian());
        let s = Bench::new(&format!("trisolve 256x{n}"))
            .with_budget(6, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(solve_xlt_eq_b(&l, &rhs));
            });
        report(&s, Some((256.0 * (n * n) as f64, "FLOP")));
    }
}
