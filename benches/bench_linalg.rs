//! Dense linear-algebra substrate throughput: gemm / Gram / Cholesky /
//! triangular solve — the flop backbone of calibration and rescaler
//! optimization.
//!
//! Benchmarks the packed micro-kernel generation AGAINST transcriptions
//! of the seed scalar kernels (row-parallel ikj matmul with
//! spawn-per-call threading; single-threaded triangle gram), and emits
//! everything to `BENCH_linalg.json` so the perf trajectory is tracked
//! from this PR onward.  Acceptance targets: ≥2× GFLOP/s on
//! `matmul 512³` and ≥4× on `gram 2048x256` versus the seed kernels,
//! ≥1.5× for the f32 path over the packed f64 kernel on `matmul 512³`,
//! and ≥3× for the blocked pool-parallel Cholesky on `chol 1024`
//! versus the seed serial factorization (`trsm <a>x<n>` rows track the
//! blocked triangular solve the same way, and a derived
//! `prepare-once factorizations` entry pins the factorization-cached
//! rate search at ONE factorization per layer — the shared
//! `PreparedStats` serves the subsample and the full system alike).
//! The ratios are recorded as `speedup <shape>` /
//! `speedup f32 <shape>` JSON entries; `dispatch`-tagged rows measure
//! the forced-scalar rung so `speedup dispatch <shape>` isolates the
//! SIMD micro-kernel win from the element-width win.  Set
//! `WATERSIC_BENCH_ENFORCE=1` to turn the targets into hard gates
//! (exit 1 on miss) — off by default because shared CI runners are too
//! noisy to fail builds on.

use std::time::Duration;

use watersic::linalg::chol::{
    cholesky, cholesky_unblocked, factorization_count, solve_xlt_eq_b,
    solve_xlt_eq_b_rowwise,
};
use watersic::linalg::gemm::{
    gram, gram_prec, matmul, matmul_f32, matmul_f32_with, matmul_nt,
    simd_backend, Precision, SimdBackend,
};
use watersic::linalg::Mat;
use watersic::util::bench::{report, Bench, BenchLog};
use watersic::util::json::Json;
use watersic::util::rng::Rng;
use watersic::util::threadpool::default_threads;

// ---------------------------------------------------------------------
// seed-kernel transcriptions (the pre-packing generation), kept here so
// every bench run re-measures the baseline on the same machine

/// Seed `matmul`: scalar ikj, BLOCK_K = 64, row-parallel with
/// spawn-per-call scoped threads — faithful to the seed including its
/// threading model.
fn seed_matmul(a: &Mat, b: &Mat) -> Mat {
    const BLOCK_K: usize = 64;
    assert_eq!(a.cols, b.rows);
    let mut c = Mat::zeros(a.rows, b.cols);
    let n = b.cols;
    let k = a.cols;
    let threads = (if a.rows * n * k > 1 << 18 {
        default_threads()
    } else {
        1
    })
    .min(a.rows.max(1));
    let chunk = a.rows.div_ceil(threads);
    let cdata = std::sync::atomic::AtomicPtr::new(c.data.as_mut_ptr());
    std::thread::scope(|scope| {
        for tid in 0..threads {
            let lo = tid * chunk;
            let hi = ((tid + 1) * chunk).min(a.rows);
            if lo >= hi {
                break;
            }
            let cdata = &cdata;
            scope.spawn(move || {
                let cptr = cdata.load(std::sync::atomic::Ordering::Relaxed);
                for i in lo..hi {
                    // SAFETY: disjoint row ranges per thread.
                    let crow =
                        unsafe { std::slice::from_raw_parts_mut(cptr.add(i * n), n) };
                    crow.fill(0.0);
                    let arow = a.row(i);
                    for k0 in (0..k).step_by(BLOCK_K) {
                        let k1 = (k0 + BLOCK_K).min(k);
                        for kk in k0..k1 {
                            let aik = arow[kk];
                            if aik == 0.0 {
                                continue;
                            }
                            let brow = b.row(kk);
                            for j in 0..n {
                                crow[j] += aik * brow[j];
                            }
                        }
                    }
                }
            });
        }
    });
    c
}

/// Seed `gram`: single-threaded upper-triangle accumulation.
fn seed_gram(a: &Mat) -> Mat {
    let n = a.cols;
    let mut c = Mat::zeros(n, n);
    for r in 0..a.rows {
        let row = a.row(r);
        for i in 0..n {
            let xi = row[i];
            if xi == 0.0 {
                continue;
            }
            let crow = c.row_mut(i);
            for j in i..n {
                crow[j] += xi * row[j];
            }
        }
    }
    for i in 0..n {
        for j in 0..i {
            c[(i, j)] = c[(j, i)];
        }
    }
    c
}

/// JSON entries whose values the `WATERSIC_BENCH_ENFORCE=1` gates at
/// the bottom of `main` enforce.  The `bench-json-sync` lint
/// (rust/xtask) requires every name listed here to be emitted into
/// BENCH_linalg.json by this file *and* pinned by a `grep` in CI — a
/// gate whose telemetry CI never checks is a gate that can rot out of
/// the artifact.
const GATED_ENTRIES: &[&str] = &[
    "speedup matmul 512³",
    "speedup gram 2048x256",
    "speedup chol 1024",
    "speedup f32 matmul 512³",
];

fn main() {
    println!("== bench_linalg: f64 dense kernels (packed vs seed) ==");
    let mut rng = Rng::new(3);
    let mut log = BenchLog::new("BENCH_linalg.json");
    log.meta("bench", Json::Str("linalg".to_string()));
    log.meta("simd_backend", Json::Str(simd_backend().name().to_string()));

    let mut packed_medians: Vec<(String, f64)> = Vec::new();
    let mut seed_medians: Vec<(String, f64)> = Vec::new();
    let mut f32_medians: Vec<(String, f64)> = Vec::new();
    let mut scalar32_medians: Vec<(String, f64)> = Vec::new();

    for n in [64usize, 128, 256, 512] {
        let a = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let b = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let flops = 2.0 * (n * n * n) as f64;

        let s = Bench::new(&format!("matmul {n}³"))
            .with_budget(6, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(matmul(&a, &b));
            });
        report(&s, Some((flops, "FLOP")));
        log.record(&s, Some(flops), "packed");
        packed_medians.push((s.name.clone(), s.median.as_secs_f64()));

        let s = Bench::new(&format!("matmul {n}³ [seed]"))
            .with_budget(4, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(seed_matmul(&a, &b));
            });
        report(&s, Some((flops, "FLOP")));
        log.record(&s, Some(flops), "seed");
        seed_medians.push((format!("matmul {n}³"), s.median.as_secs_f64()));

        // f32 packed path (dispatched kernel)
        let s = Bench::new(&format!("matmul {n}³ [f32]"))
            .with_budget(6, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(matmul_f32(&a, &b));
            });
        report(&s, Some((flops, "FLOP")));
        log.record(&s, Some(flops), "f32");
        f32_medians.push((format!("matmul {n}³"), s.median.as_secs_f64()));

        // forced-scalar rung of the f32 ladder: isolates the SIMD
        // dispatch win from the element-width win
        let s = Bench::new(&format!("matmul {n}³ [f32 scalar]"))
            .with_budget(4, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(matmul_f32_with(
                    &a,
                    &b,
                    default_threads(),
                    SimdBackend::Scalar,
                ));
            });
        report(&s, Some((flops, "FLOP")));
        log.record(&s, Some(flops), "dispatch");
        scalar32_medians.push((format!("matmul {n}³"), s.median.as_secs_f64()));

        let s = Bench::new(&format!("matmul_nt {n}³"))
            .with_budget(6, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(matmul_nt(&a, &b));
            });
        report(&s, Some((flops, "FLOP")));
        log.record(&s, Some(flops), "packed");
    }

    for n in [64usize, 128, 256] {
        let panel = Mat::from_fn(2048, n, |_, _| rng.gaussian());
        let flops = 2048.0 * (n * n) as f64;

        let s = Bench::new(&format!("gram 2048x{n}"))
            .with_budget(6, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(gram(&panel));
            });
        report(&s, Some((flops, "FLOP")));
        log.record(&s, Some(flops), "packed");
        packed_medians.push((s.name.clone(), s.median.as_secs_f64()));

        let s = Bench::new(&format!("gram 2048x{n} [seed]"))
            .with_budget(4, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(seed_gram(&panel));
            });
        report(&s, Some((flops, "FLOP")));
        log.record(&s, Some(flops), "seed");
        seed_medians.push((format!("gram 2048x{n}"), s.median.as_secs_f64()));

        let s = Bench::new(&format!("gram 2048x{n} [f32]"))
            .with_budget(6, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(gram_prec(&panel, Precision::F32));
            });
        report(&s, Some((flops, "FLOP")));
        log.record(&s, Some(flops), "f32");
        f32_medians.push((format!("gram 2048x{n}"), s.median.as_secs_f64()));

        let mut spd = gram(&panel).scale(1.0 / 2048.0);
        spd.add_diag(0.01);
        let s = Bench::new(&format!("cholesky {n}"))
            .with_budget(6, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(cholesky(&spd).unwrap());
            });
        report(&s, Some(((n * n * n) as f64 / 3.0, "FLOP")));
        log.record(&s, Some((n * n * n) as f64 / 3.0), "packed");
        let l = cholesky(&spd).unwrap();
        let rhs = Mat::from_fn(256, n, |_, _| rng.gaussian());
        let s = Bench::new(&format!("trisolve 256x{n}"))
            .with_budget(6, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(solve_xlt_eq_b(&l, &rhs));
            });
        report(&s, Some((256.0 * (n * n) as f64, "FLOP")));
        log.record(&s, Some(256.0 * (n * n) as f64), "packed");
    }

    // ---- blocked factorization layer vs the seed kernels: the secant
    // front-end at Llama-ish widths (analytic AR(1) SPD so setup cost
    // stays off the clock)
    println!("\n== factorization front-end (blocked vs seed) ==");
    for n in [256usize, 512, 1024] {
        let mut spd = watersic::quant::waterfilling::ar1_sigma(n, 0.9);
        spd.add_diag(0.05);
        let flops = (n * n * n) as f64 / 3.0;

        let s = Bench::new(&format!("chol {n}"))
            .with_budget(5, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(cholesky(&spd).unwrap());
            });
        report(&s, Some((flops, "FLOP")));
        log.record(&s, Some(flops), "packed");
        packed_medians.push((s.name.clone(), s.median.as_secs_f64()));

        let s = Bench::new(&format!("chol {n} [seed]"))
            .with_budget(3, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(cholesky_unblocked(&spd).unwrap());
            });
        report(&s, Some((flops, "FLOP")));
        log.record(&s, Some(flops), "seed");
        seed_medians.push((format!("chol {n}"), s.median.as_secs_f64()));
    }
    for (a, n) in [(256usize, 512usize), (512, 1024)] {
        let mut spd = watersic::quant::waterfilling::ar1_sigma(n, 0.9);
        spd.add_diag(0.05);
        let l = cholesky(&spd).unwrap();
        let rhs = Mat::from_fn(a, n, |_, _| rng.gaussian());
        let flops = (a * n * n) as f64;

        let s = Bench::new(&format!("trsm {a}x{n}"))
            .with_budget(5, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(solve_xlt_eq_b(&l, &rhs));
            });
        report(&s, Some((flops, "FLOP")));
        log.record(&s, Some(flops), "packed");
        packed_medians.push((s.name.clone(), s.median.as_secs_f64()));

        let s = Bench::new(&format!("trsm {a}x{n} [seed]"))
            .with_budget(3, Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(solve_xlt_eq_b_rowwise(&l, &rhs));
            });
        report(&s, Some((flops, "FLOP")));
        log.record(&s, Some(flops), "seed");
        seed_medians.push((format!("trsm {a}x{n}"), s.median.as_secs_f64()));
    }

    // ---- prepare-once pipeline counter: a rate-targeted layer must
    // factor exactly once — the shared PreparedStats serves both the
    // subsample system and the full system — however many secant
    // probes run
    {
        use watersic::quant::{watersic::watersic_at_rate, LayerStats, QuantOpts};
        let a = 128usize;
        let n = 96usize;
        let mut sigma = watersic::quant::waterfilling::ar1_sigma(n, 0.9);
        sigma.add_diag(0.05);
        let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
        let stats = LayerStats::from_sigma(sigma);
        let opts = QuantOpts {
            rescalers: false, // the Γ-step's own factorizations are not front-end
            ..QuantOpts::default()
        };
        let before = factorization_count();
        watersic_at_rate(&w, &stats, 2.5, &opts, None, 64, 0).unwrap();
        let per_layer = (factorization_count() - before) as f64;
        println!("\nprepare-once factorizations per rate-targeted layer: {per_layer}");
        log.note("prepare-once factorizations", per_layer);
    }

    // ---- derived speedups (seed median / packed median per shape)
    println!("\n-- speedups vs seed kernels --");
    let mut speedups: Vec<(String, f64)> = Vec::new();
    for (name, seed_t) in &seed_medians {
        if let Some((_, packed_t)) =
            packed_medians.iter().find(|(n, _)| n == name)
        {
            if *packed_t > 0.0 {
                let speedup = seed_t / packed_t;
                println!("{name:44} {speedup:6.2}×");
                log.note(&format!("speedup {name}"), speedup);
                speedups.push((name.clone(), speedup));
            }
        }
    }

    // ---- f32 path vs the packed f64 kernel, per shape
    println!("\n-- f32 speedups vs packed f64 --");
    let mut f32_speedups: Vec<(String, f64)> = Vec::new();
    for (name, f32_t) in &f32_medians {
        if let Some((_, packed_t)) =
            packed_medians.iter().find(|(n, _)| n == name)
        {
            if *f32_t > 0.0 {
                let speedup = packed_t / f32_t;
                println!("{name:44} {speedup:6.2}×");
                log.note(&format!("speedup f32 {name}"), speedup);
                f32_speedups.push((name.clone(), speedup));
            }
        }
    }

    // ---- dispatched kernel vs the forced-scalar rung (f32)
    println!("\n-- dispatch speedups vs scalar rung (f32) --");
    for (name, scalar_t) in &scalar32_medians {
        if let Some((_, f32_t)) = f32_medians.iter().find(|(n, _)| n == name) {
            if *f32_t > 0.0 {
                let speedup = scalar_t / f32_t;
                println!("{name:44} {speedup:6.2}×");
                log.note(&format!("speedup dispatch {name}"), speedup);
            }
        }
    }

    match log.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("failed to write bench log: {e}"),
    }

    // opt-in hard gates (see module docs)
    if watersic::util::env::flag("WATERSIC_BENCH_ENFORCE") {
        println!("enforcing entries: {}", GATED_ENTRIES.join(", "));
        let gates = [
            ("matmul 512³", 2.0),
            ("gram 2048x256", 4.0),
            // blocked pool-parallel Cholesky vs the seed serial kernel
            ("chol 1024", 3.0),
        ];
        let mut failed = false;
        for (shape, min) in gates {
            let got = speedups
                .iter()
                .find(|(n, _)| n == shape)
                .map(|(_, s)| *s)
                .unwrap_or(0.0);
            if got < min {
                eprintln!("GATE FAILED: {shape} speedup {got:.2}× < {min}×");
                failed = true;
            } else {
                println!("gate ok: {shape} {got:.2}× ≥ {min}×");
            }
        }
        // f32 path must beat the packed f64 kernel on the flagship shape
        let f32_gates = [("matmul 512³", 1.5)];
        for (shape, min) in f32_gates {
            let got = f32_speedups
                .iter()
                .find(|(n, _)| n == shape)
                .map(|(_, s)| *s)
                .unwrap_or(0.0);
            if got < min {
                eprintln!(
                    "GATE FAILED: {shape} f32 speedup {got:.2}× < {min}×"
                );
                failed = true;
            } else {
                println!("gate ok: {shape} f32 {got:.2}× ≥ {min}×");
            }
        }
        if failed {
            std::process::exit(1);
        }
    }
}
