//! Entropy coder throughput and rate efficiency on realistic ZSIC code
//! distributions (the container hot path).

use std::time::Duration;

use watersic::entropy::external::ZstdCodec;
use watersic::entropy::huffman::Huffman;
use watersic::entropy::rans::Rans;
use watersic::entropy::{entropy_bits, Codec};
use watersic::util::bench::{report, Bench};
use watersic::util::rng::Rng;

fn main() {
    println!("== bench_entropy: coder throughput / rate efficiency ==");
    let mut rng = Rng::new(2);
    for sigma in [1.0f64, 4.0] {
        let z: Vec<i32> = (0..1_000_000)
            .map(|_| (rng.gaussian() * sigma).round_ties_even() as i32)
            .collect();
        let ent = entropy_bits(&z);
        println!("\n1M symbols, σ={sigma} (entropy {ent:.3} bits):");
        for codec in [&Huffman as &dyn Codec, &Rans, &ZstdCodec] {
            let enc = codec.encode(&z);
            let rate = 8.0 * enc.len() as f64 / z.len() as f64;
            let se = Bench::new(&format!("{} encode", codec.name()))
                .with_budget(5, Duration::from_secs(2))
                .run(|| {
                    std::hint::black_box(codec.encode(&z));
                });
            report(&se, Some((z.len() as f64 * 4.0, "B")));
            let sd = Bench::new(&format!("{} decode", codec.name()))
                .with_budget(5, Duration::from_secs(2))
                .run(|| {
                    std::hint::black_box(codec.decode(&enc, z.len()).unwrap());
                });
            report(&sd, Some((z.len() as f64 * 4.0, "B")));
            println!(
                "{:>44}   rate {rate:.3} bits (+{:.3} over entropy)",
                "",
                rate - ent
            );
        }
    }
}
