//! End-to-end coordinator latency: full-model quantization wall time per
//! algorithm (the paper's practical-cost axis), on the real trained
//! picollama_s with artifacts when available.  Emits
//! `BENCH_pipeline.json` alongside the console table, including the
//! streaming-prepare telemetry — `prepare peak pairs` (high-water mark
//! of simultaneously-alive prepared front-ends, ≤ the
//! `WATERSIC_PREPARE_LOOKAHEAD` window) and per-layer `factorizations`
//! (1 with the shared-stats `PreparedStats`) — measured on a synthetic
//! model so the entries exist even where no artifacts do (CI smoke).

use std::time::Duration;

use watersic::calib::corpus::Corpus;
use watersic::coordinator::{quantize_model, Algo, PipelineOpts};
use watersic::experiments::{llm::pipeline_opts, Ctx};
use watersic::linalg::chol::factorization_count_global;
use watersic::model::weights::Weights;
use watersic::model::ModelConfig;
use watersic::util::bench::{report, Bench, BenchLog};
use watersic::util::json::Json;

/// Streaming-prepare telemetry on a synthetic tiny model: always
/// available, deterministic, and cheap enough for the CI smoke run.
fn prepare_telemetry(log: &mut BenchLog) -> anyhow::Result<()> {
    let cfg = ModelConfig::tiny_test();
    let teacher = Weights::random(&cfg, 21);
    let text: String = (0..400)
        .map(|i| format!("alpha beta {} gamma. ", i % 37))
        .collect();
    let corpus = Corpus::from_bytes("bench", text.into_bytes());
    let mut opts = PipelineOpts::watersic(3.0);
    opts.calib_windows = 4;
    opts.calib_batch = 2;
    opts.use_engine = false;
    opts.subsample_rows = 16;
    // only front-end factorizations count (the Γ-step has its own)
    opts.quant.rescalers = false;
    let before = factorization_count_global();
    let qm = quantize_model(&cfg, &teacher, &corpus, &opts, None)?;
    let per_layer = (factorization_count_global() - before) as f64
        / qm.report.matrices.len() as f64;
    println!(
        "prepare peak pairs: {} (window {})   factorizations/layer: {per_layer}",
        qm.report.prepare_peak_pairs, opts.prepare_lookahead
    );
    log.note("prepare peak pairs", qm.report.prepare_peak_pairs as f64);
    log.note("factorizations", per_layer);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("== bench_pipeline: full-model quantization latency ==");
    let mut log = BenchLog::new("BENCH_pipeline.json");
    log.meta("bench", Json::Str("pipeline".to_string()));
    prepare_telemetry(&mut log)?;
    let ctx = Ctx::new(true, true)?;
    let Ok((cfg, teacher)) = ctx.load_model("picollama_s") else {
        println!("skipped: run `make artifacts` first");
        log.meta("skipped", Json::Bool(true));
        if let Ok(path) = log.write() {
            println!("wrote {}", path.display());
        }
        return Ok(());
    };
    let wiki = ctx.load_corpus("wiki")?;
    for (label, algo) in [
        ("huffman-rtn", Algo::HuffRtn),
        ("huffman-gptq", Algo::HuffGptq),
        ("watersic", Algo::WaterSic),
    ] {
        let opts = pipeline_opts(&ctx, algo, 2.0, false);
        let s = Bench::new(&format!("pipeline {label} @2.0"))
            .with_budget(3, Duration::from_secs(12))
            .run(|| {
                std::hint::black_box(
                    quantize_model(&cfg, &teacher, &wiki, &opts, ctx.engine.as_ref())
                        .unwrap(),
                );
            });
        report(
            &s,
            Some((cfg.quantizable_params() as f64, "weights")),
        );
        log.record(&s, None, "packed");
    }
    // the PJRT-vs-native ZSIC split inside the pipeline
    for use_engine in [false, true] {
        let mut opts = pipeline_opts(&ctx, Algo::WaterSic, 2.0, false);
        opts.use_engine = use_engine;
        let s = Bench::new(&format!(
            "watersic zsic-exec={}",
            if use_engine { "pjrt" } else { "native" }
        ))
        .with_budget(3, Duration::from_secs(12))
        .run(|| {
            std::hint::black_box(
                quantize_model(&cfg, &teacher, &wiki, &opts, ctx.engine.as_ref())
                    .unwrap(),
            );
        });
        report(&s, Some((cfg.quantizable_params() as f64, "weights")));
        log.record(&s, None, "packed");
    }
    let path = log.write()?;
    println!("wrote {}", path.display());
    Ok(())
}
