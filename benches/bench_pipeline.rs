//! End-to-end coordinator latency: full-model quantization wall time per
//! algorithm (the paper's practical-cost axis), on the real trained
//! picollama_s with artifacts when available.  Emits
//! `BENCH_pipeline.json` alongside the console table.

use std::time::Duration;

use watersic::coordinator::{quantize_model, Algo};
use watersic::experiments::{llm::pipeline_opts, Ctx};
use watersic::util::bench::{report, Bench, BenchLog};
use watersic::util::json::Json;

fn main() -> anyhow::Result<()> {
    println!("== bench_pipeline: full-model quantization latency ==");
    let mut log = BenchLog::new("BENCH_pipeline.json");
    log.meta("bench", Json::Str("pipeline".to_string()));
    let ctx = Ctx::new(true, true)?;
    let Ok((cfg, teacher)) = ctx.load_model("picollama_s") else {
        println!("skipped: run `make artifacts` first");
        log.meta("skipped", Json::Bool(true));
        if let Ok(path) = log.write() {
            println!("wrote {}", path.display());
        }
        return Ok(());
    };
    let wiki = ctx.load_corpus("wiki")?;
    for (label, algo) in [
        ("huffman-rtn", Algo::HuffRtn),
        ("huffman-gptq", Algo::HuffGptq),
        ("watersic", Algo::WaterSic),
    ] {
        let opts = pipeline_opts(&ctx, algo, 2.0, false);
        let s = Bench::new(&format!("pipeline {label} @2.0"))
            .with_budget(3, Duration::from_secs(12))
            .run(|| {
                std::hint::black_box(
                    quantize_model(&cfg, &teacher, &wiki, &opts, ctx.engine.as_ref())
                        .unwrap(),
                );
            });
        report(
            &s,
            Some((cfg.quantizable_params() as f64, "weights")),
        );
        log.record(&s, None, "packed");
    }
    // the PJRT-vs-native ZSIC split inside the pipeline
    for use_engine in [false, true] {
        let mut opts = pipeline_opts(&ctx, Algo::WaterSic, 2.0, false);
        opts.use_engine = use_engine;
        let s = Bench::new(&format!(
            "watersic zsic-exec={}",
            if use_engine { "pjrt" } else { "native" }
        ))
        .with_budget(3, Duration::from_secs(12))
        .run(|| {
            std::hint::black_box(
                quantize_model(&cfg, &teacher, &wiki, &opts, ctx.engine.as_ref())
                    .unwrap(),
            );
        });
        report(&s, Some((cfg.quantizable_params() as f64, "weights")));
        log.record(&s, None, "packed");
    }
    let path = log.write()?;
    println!("wrote {}", path.display());
    Ok(())
}
