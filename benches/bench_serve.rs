//! Serving-engine performance: prepack-vs-repack GEMM speedup plus
//! end-to-end micro-batched serving throughput/latency on the
//! quantized synthetic tiny model.  Emits `BENCH_serve.json` — the CI
//! serve-smoke job greps the `speedup prepack <shape>` entry and the
//! `serve throughput tok/s` / `serve p50|p90|p99 ms` percentiles.
//!
//! The prepack rows measure exactly what the server removes from the
//! hot path: `repack`-tagged rows run the public pack-per-call driver
//! (`matmul_nt_prec`, B re-packed every call), `prepack` rows run
//! [`matmul_prepacked`] over panels packed once up front.  Skinny
//! activation panels (few tokens per weight matrix — the serving
//! regime) amortize the pack worst, so the m=16 shape is the headline.
//! `WATERSIC_BENCH_ENFORCE=1` turns a modest ≥1.05× gate on the m=16
//! shape into a hard failure (off by default: shared runners are too
//! noisy to fail builds on).
//!
//! Load-test knobs: `WATERSIC_SERVE_CLIENTS` (default 8; the CI gate
//! needs ≥8 concurrent) and `WATERSIC_SERVE_REQUESTS` per client
//! (default 8), on top of the engine's `WATERSIC_SERVE_BATCH` /
//! `WATERSIC_SERVE_FLUSH_US` / `WATERSIC_PRECISION` options.

use std::time::Duration;

use watersic::coordinator::container::Container;
use watersic::coordinator::quantize_model;
use watersic::experiments::{synthetic_tiny_opts, synthetic_tiny_setup};
use watersic::linalg::gemm::{matmul_nt_prec, matmul_prepacked, Precision, PrepackedB};
use watersic::linalg::Mat;
use watersic::runtime::server::{load_test, serve_batch_from_env, Server};
use watersic::runtime::ServeOpts;
use watersic::util::bench::{report, Bench, BenchLog};
use watersic::util::json::Json;
use watersic::util::rng::Rng;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(default)
        .max(1)
}

fn main() -> anyhow::Result<()> {
    println!("== bench_serve: prepacked-weight serving engine ==");
    let prec = Precision::from_env();
    let mut log = BenchLog::new("BENCH_serve.json");
    log.meta("bench", Json::Str("serve".to_string()));
    log.meta("precision", Json::Str(prec.name().to_string()));

    // ---- prepack vs repack: projection GEMMs at serving shapes
    // (m tokens through an a×n weight, C = X·Wᵀ)
    let mut rng = Rng::new(31);
    let mut prepack_speedups: Vec<(String, f64)> = Vec::new();
    for (m, a, n) in [(16usize, 512usize, 512usize), (128, 512, 512), (16, 2048, 512)] {
        let x = Mat::from_fn(m, n, |_, _| rng.gaussian());
        let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
        let name = format!("{m}x{n}x{a}");
        let flops = (2 * m * n * a) as f64;

        let s_repack = Bench::new(&format!("nt repack {name}"))
            .with_budget(8, Duration::from_secs(3))
            .run(|| {
                std::hint::black_box(matmul_nt_prec(&x, &w, prec));
            });
        report(&s_repack, Some((flops, "flop")));
        log.record(&s_repack, Some(flops), "repack");

        let pb = PrepackedB::pack_nt(&w, prec);
        let s_prepack = Bench::new(&format!("nt prepack {name}"))
            .with_budget(8, Duration::from_secs(3))
            .run(|| {
                std::hint::black_box(matmul_prepacked(&x, &pb));
            });
        report(&s_prepack, Some((flops, "flop")));
        log.record(&s_prepack, Some(flops), "prepack");

        let speedup = s_repack.median.as_secs_f64() / s_prepack.median.as_secs_f64();
        println!("speedup prepack {name}: {speedup:.2}×");
        log.note(&format!("speedup prepack {name}"), speedup);
        prepack_speedups.push((name, speedup));
    }

    // ---- end-to-end: quantize the synthetic tiny model, serve it,
    // drive it with concurrent clients
    let (cfg, teacher, corpus) = synthetic_tiny_setup();
    let opts = synthetic_tiny_opts(3.0);
    let qm = quantize_model(&cfg, &teacher, &corpus, &opts, None)?;
    let container = Container::new(&cfg.name, qm.quants.clone());
    println!(
        "quantized synthetic tiny model: {:.1} KiB container",
        container.size_bytes() as f64 / 1024.0
    );
    let server = Server::from_container(
        &cfg,
        &teacher,
        &container,
        prec,
        ServeOpts::default(),
    )?;
    let clients = env_usize("WATERSIC_SERVE_CLIENTS", 8);
    let per_client = env_usize("WATERSIC_SERVE_REQUESTS", 8);
    let rep = load_test(&server, clients, per_client, 99)?;
    rep.print();
    log.meta("serve clients", Json::Num(clients as f64));
    log.meta("serve batch max", Json::Num(serve_batch_from_env() as f64));
    log.note("serve throughput tok/s", rep.throughput_tok_s);
    log.note("serve p50 ms", rep.p50_ms);
    log.note("serve p90 ms", rep.p90_ms);
    log.note("serve p99 ms", rep.p99_ms);
    log.note("serve mean batch", rep.mean_batch);
    log.note("serve max batch", rep.max_batch as f64);
    let stats = server.shutdown();
    println!(
        "served {} requests in {} batches ({} tokens)",
        stats.requests, stats.batches, stats.tokens
    );

    match log.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write bench log: {e}"),
    }

    // opt-in hard gate (see module docs)
    if std::env::var("WATERSIC_BENCH_ENFORCE").as_deref() == Ok("1") {
        let (shape, min) = ("16x512x512", 1.05);
        let got = prepack_speedups
            .iter()
            .find(|(n, _)| n == shape)
            .map(|(_, s)| *s)
            .unwrap_or(0.0);
        if got < min {
            eprintln!("GATE FAILED: prepack {shape} speedup {got:.2}× < {min}×");
            std::process::exit(1);
        }
        println!("gate ok: prepack {shape} {got:.2}× ≥ {min}×");
    }
    Ok(())
}
