//! Serving-engine performance: prepack-vs-repack GEMM speedup,
//! KV-cache decode vs full-window re-score, and end-to-end
//! continuous-batched serving throughput/latency on the quantized
//! synthetic tiny model.  Emits `BENCH_serve.json` — the CI
//! serve-smoke job greps the `speedup prepack <shape>` entry, the
//! `decode tok/s <window>` / `speedup decode <window>` pair, and the
//! `serve throughput tok/s` / TTFT / inter-token percentiles, and the
//! overload-smoke job greps the `shed frac 2x` / `p99 under overload
//! ms` pair from the open-loop section.
//!
//! The prepack rows measure exactly what the server removes from the
//! hot path: `repack`-tagged rows run the public pack-per-call driver
//! (`matmul_nt_prec`, B re-packed every call), `prepack` rows run
//! [`matmul_prepacked`] over panels packed once up front.  Skinny
//! activation panels (few tokens per weight matrix — the serving
//! regime) amortize the pack worst, so the m=16 shape is the headline.
//!
//! The decode rows measure what the KV cache removes: the `rescore`
//! baseline is the PR 5 generation loop (every token re-runs the full
//! window forward — O(t²) attention per token), the `decode` rows run
//! one-token [`decode_packed`] steps against the cache (O(t) per
//! token).
//!
//! The coded-residency rows measure what serving straight from
//! quantized codes buys: a wide synthetic model (eager panels far
//! larger than last-level cache) is loaded both ways —
//! `from_container` (eager dequantized panels) vs
//! `from_container_coded` (bit-packed codes resident, dequantized per
//! KC block inside the GEMM pack stage) — and batched decode at
//! window 256 is timed through each.  The token traces are asserted
//! identical (the coded path is bit-for-bit the dequant path), and
//! the emitted `coded bytes resident` / `dequant bytes resident` /
//! `artifact code bytes` triple plus `coded decode tok/s 256` /
//! `dequant decode tok/s 256` / `speedup coded decode 256` are what
//! the CI coded-serve job greps.  Under `WATERSIC_BENCH_ENFORCE=1`
//! the coded resident bytes must stay ≤ 1.25× the entropy-coded
//! artifact's code plane and the coded decode speedup must be ≥ 1×.
//!
//! The open-loop rows measure what bounded admission buys under
//! overload: a saturating probe pins the service rate, then arrivals
//! at 2× that rate must be shed cleanly at admission while the
//! *accepted*-request p99 stays within a fixed multiple of the
//! uncontended p99 — overload turns into fast `overloaded` rejections
//! instead of unbounded queueing delay.  `WATERSIC_BENCH_ENFORCE=1`
//! turns the modest ≥1.05× prepack gate, the ≥10× decode-speedup gate
//! at window 256, and the overload gates (zero errors, sheds present,
//! bounded accepted p99) into hard failures (off by default: shared
//! runners are too noisy to fail builds on).
//!
//! Load-test knobs: `WATERSIC_SERVE_CLIENTS` (default 8; the CI gate
//! needs ≥8 concurrent) and `WATERSIC_SERVE_REQUESTS` per client
//! (default 8), on top of the engine's `WATERSIC_SERVE_BATCH` /
//! `WATERSIC_SERVE_FLUSH_US` / `WATERSIC_SERVE_KV_BUDGET` /
//! `WATERSIC_SERVE_MAX_STEPS` / `WATERSIC_PRECISION` options.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use watersic::coordinator::container::Container;
use watersic::coordinator::quantize_model;
use watersic::experiments::{synthetic_tiny_opts, synthetic_tiny_setup};
use watersic::linalg::gemm::{matmul_nt_prec, matmul_prepacked, Precision, PrepackedB};
use watersic::linalg::Mat;
use watersic::model::transformer::{
    argmax_last, decode_packed, forward_packed, prefill_packed, ForwardOpts, KvCache,
};
use watersic::model::weights::{PackedWeights, Weights};
use watersic::model::ModelConfig;
use watersic::quant::LayerQuant;
use watersic::runtime::server::{
    load_test, load_test_open, serve_batch_from_env, LoadMix, Server,
};
use watersic::runtime::ServeOpts;
use watersic::util::bench::{report, Bench, BenchLog};
use watersic::util::json::Json;
use watersic::util::rng::Rng;

fn env_usize(key: &'static str, default: usize) -> usize {
    watersic::util::env::usize_or(key, default).max(1)
}

/// JSON entries whose values the `WATERSIC_BENCH_ENFORCE=1` gates
/// below enforce.  The `bench-json-sync` lint (rust/xtask) requires
/// every name listed here to be emitted into BENCH_serve.json by this
/// file *and* pinned by a `grep` in CI — a gate whose telemetry CI
/// never checks is a gate that can rot out of the artifact.
const GATED_ENTRIES: &[&str] = &[
    "speedup prepack 16x512x512",
    "speedup decode 256",
    "shed frac 2x",
    "p99 under overload ms",
    "coded bytes resident",
    "speedup coded decode 256",
];

fn main() -> anyhow::Result<()> {
    println!("== bench_serve: continuous-batching serving engine ==");
    let prec = Precision::from_env();
    let mut log = BenchLog::new("BENCH_serve.json");
    log.meta("bench", Json::Str("serve".to_string()));
    log.meta("precision", Json::Str(prec.name().to_string()));

    // ---- prepack vs repack: projection GEMMs at serving shapes
    // (m tokens through an a×n weight, C = X·Wᵀ)
    let mut rng = Rng::new(31);
    let mut prepack_speedups: Vec<(String, f64)> = Vec::new();
    for (m, a, n) in [(16usize, 512usize, 512usize), (128, 512, 512), (16, 2048, 512)] {
        let x = Mat::from_fn(m, n, |_, _| rng.gaussian());
        let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
        let name = format!("{m}x{n}x{a}");
        let flops = (2 * m * n * a) as f64;

        let s_repack = Bench::new(&format!("nt repack {name}"))
            .with_budget(8, Duration::from_secs(3))
            .run(|| {
                std::hint::black_box(matmul_nt_prec(&x, &w, prec));
            });
        report(&s_repack, Some((flops, "flop")));
        log.record(&s_repack, Some(flops), "repack");

        let pb = PrepackedB::pack_nt(&w, prec);
        let s_prepack = Bench::new(&format!("nt prepack {name}"))
            .with_budget(8, Duration::from_secs(3))
            .run(|| {
                std::hint::black_box(matmul_prepacked(&x, &pb));
            });
        report(&s_prepack, Some((flops, "flop")));
        log.record(&s_prepack, Some(flops), "prepack");

        let speedup = s_repack.median.as_secs_f64() / s_prepack.median.as_secs_f64();
        println!("speedup prepack {name}: {speedup:.2}×");
        log.note(&format!("speedup prepack {name}"), speedup);
        prepack_speedups.push((name, speedup));
    }

    // ---- KV-cache decode vs full-window re-score at window 256: a
    // wider-than-tiny model so attention actually costs something,
    // with ctx headroom so no decode step needs a window reslide
    let dcfg = ModelConfig {
        vocab: 256,
        d_model: 64,
        n_heads: 4,
        n_layers: 2,
        d_ff: 128,
        ctx: 384,
        ..ModelConfig::tiny_test()
    };
    let window = 256usize;
    let dw = PackedWeights::new(&dcfg, Weights::random(&dcfg, 17), prec);
    let mut drng = Rng::new(5);
    let prompt: Vec<i32> = (0..window)
        .map(|_| drng.below(dcfg.vocab) as i32)
        .collect();

    // PR 5 baseline: every generated token re-runs the full window
    // forward (O(t²) attention per token)
    let rescore_steps = 8usize;
    let mut toks = prompt.clone();
    let t0 = Instant::now();
    for _ in 0..rescore_steps {
        let t = toks.len().min(dcfg.ctx);
        let win = &toks[toks.len() - t..];
        let out = forward_packed(&dcfg, &dw, win, 1, t, &ForwardOpts::default());
        toks.push(argmax_last(out.logits.row(t - 1)) as i32);
    }
    let rescore_tok_s = rescore_steps as f64 / t0.elapsed().as_secs_f64().max(1e-9);

    // cached path: prefill the prompt once, then one-token decode
    // steps against the per-sequence KV cache (O(t) per token)
    let decode_steps = 96usize;
    let mut cache = KvCache::new(&dcfg, dcfg.ctx);
    let mut toks = prompt.clone();
    {
        let mut kv = [Some((&mut cache, window))];
        let out = prefill_packed(
            &dcfg,
            &dw,
            &toks,
            1,
            window,
            &mut kv,
            &ForwardOpts::default(),
        );
        toks.push(argmax_last(out.logits.row(window - 1)) as i32);
    }
    let t0 = Instant::now();
    for _ in 0..decode_steps {
        let last = *toks.last().unwrap();
        let mut caches = [&mut cache];
        let logits = decode_packed(&dcfg, &dw, &[last], &mut caches);
        toks.push(argmax_last(logits.row(0)) as i32);
    }
    let decode_tok_s = decode_steps as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    let decode_speedup = decode_tok_s / rescore_tok_s.max(1e-9);
    println!(
        "decode tok/s {window}: {decode_tok_s:.0}  (rescore {rescore_tok_s:.0} tok/s, speedup {decode_speedup:.1}×)"
    );
    log.note(&format!("decode tok/s {window}"), decode_tok_s);
    log.note(&format!("rescore tok/s {window}"), rescore_tok_s);
    log.note(&format!("speedup decode {window}"), decode_speedup);

    // ---- coded weight residency: serve straight from quantized
    // codes.  A wide synthetic model — eager panels ~100 MiB, far
    // larger than last-level cache — quantized to narrow codes, then
    // loaded both ways.  At decode widths the eager path streams the
    // full panels from RAM every step; the coded path keeps ~7 MiB of
    // bit-packed codes resident and decodes per KC block (in
    // parallel) into a cache-sized scratch panel, so it trades
    // memory-bound panel traffic for compute that fits in cache.
    let ccfg = ModelConfig {
        vocab: 256,
        d_model: 512,
        n_heads: 8,
        n_layers: 3,
        d_ff: 2048,
        ctx: 384,
        ..ModelConfig::tiny_test()
    };
    let cbase = Weights::random(&ccfg, 23);
    let mut qrng = Rng::new(40);
    let mut quants = BTreeMap::new();
    let mut qnames: Vec<String> = Vec::new();
    for i in 0..ccfg.n_layers {
        for s in [
            "attn.wq", "attn.wk", "attn.wv", "attn.wo", "ffn.w1", "ffn.w3", "ffn.w2",
        ] {
            qnames.push(format!("layers.{i}.{s}"));
        }
    }
    qnames.push("head".to_string());
    for name in &qnames {
        let (a, n) = ccfg.shape_of(name);
        let z: Vec<i32> = (0..a * n)
            .map(|_| ((qrng.gaussian() * 5.0).round() as i32).clamp(-7, 7))
            .collect();
        let alphas: Vec<f64> = (0..n).map(|_| 0.01 + 0.01 * qrng.uniform()).collect();
        let gammas: Vec<f64> = (0..n).map(|_| 0.9 + 0.2 * qrng.uniform()).collect();
        let t: Vec<f64> = (0..a).map(|_| 0.9 + 0.2 * qrng.uniform()).collect();
        quants.insert(
            name.clone(),
            LayerQuant {
                a,
                n,
                z,
                alphas,
                gammas,
                t,
                entropy_bits: 0.0,
                rate_bits: 0.0,
                dead_cols: Vec::new(),
            },
        );
    }
    let ccontainer = Container::new("coded_bench", quants);
    let artifact_code_bytes = ccontainer.code_bytes();
    let pw_dequant = PackedWeights::from_container(&ccfg, &cbase, &ccontainer, prec)?;
    let pw_coded = PackedWeights::from_container_coded(&ccfg, &cbase, &ccontainer, prec)?;
    let dequant_resident = pw_dequant.packed_bytes();
    let coded_resident = pw_coded.packed_bytes();
    println!(
        "coded residency: {:.1} MiB eager panels -> {:.2} MiB coded ({} coded projections; artifact code plane {:.2} MiB)",
        dequant_resident as f64 / (1024.0 * 1024.0),
        coded_resident as f64 / (1024.0 * 1024.0),
        pw_coded.coded_count(),
        artifact_code_bytes as f64 / (1024.0 * 1024.0),
    );
    log.note("dequant bytes resident", dequant_resident as f64);
    log.note("coded bytes resident", coded_resident as f64);
    log.note("artifact code bytes", artifact_code_bytes as f64);

    // batched decode at window 256 through each residency: prefill 8
    // sequences once, then time full-batch decode steps (2 warmup).
    // The returned token trace doubles as the bit-identity check —
    // any reconstruction difference would change an argmax somewhere
    // over 12 greedy steps × 8 sequences × 3 layers.
    let cbatch = 8usize;
    let coded_steps = 10usize;
    let mut crng = Rng::new(6);
    let cprompt: Vec<i32> = (0..cbatch * window)
        .map(|_| crng.below(ccfg.vocab) as i32)
        .collect();
    let run_decode = |pw: &PackedWeights| -> (f64, Vec<i32>) {
        let mut caches: Vec<KvCache> =
            (0..cbatch).map(|_| KvCache::new(&ccfg, ccfg.ctx)).collect();
        let mut kv: Vec<Option<(&mut KvCache, usize)>> =
            caches.iter_mut().map(|c| Some((c, window))).collect();
        let out = prefill_packed(
            &ccfg,
            pw,
            &cprompt,
            cbatch,
            window,
            &mut kv,
            &ForwardOpts::default(),
        );
        drop(kv);
        let mut last: Vec<i32> = (0..cbatch)
            .map(|s| argmax_last(out.logits.row(s * window + window - 1)) as i32)
            .collect();
        let mut trace = last.clone();
        let mut elapsed = Duration::ZERO;
        for step in 0..coded_steps + 2 {
            let t0 = Instant::now();
            let logits = {
                let mut cs: Vec<&mut KvCache> = caches.iter_mut().collect();
                decode_packed(&ccfg, pw, &last, &mut cs)
            };
            if step >= 2 {
                elapsed += t0.elapsed();
            }
            last = (0..cbatch)
                .map(|s| argmax_last(logits.row(s)) as i32)
                .collect();
            trace.extend_from_slice(&last);
        }
        let tok_s = (cbatch * coded_steps) as f64 / elapsed.as_secs_f64().max(1e-9);
        (tok_s, trace)
    };
    let (dequant_tok_s, dequant_trace) = run_decode(&pw_dequant);
    let (coded_tok_s, coded_trace) = run_decode(&pw_coded);
    assert_eq!(
        dequant_trace, coded_trace,
        "coded residency diverged from dequant — bit-identity broken"
    );
    let coded_speedup = coded_tok_s / dequant_tok_s.max(1e-9);
    println!(
        "coded decode tok/s {window}: {coded_tok_s:.0}  (dequant {dequant_tok_s:.0} tok/s, speedup {coded_speedup:.2}×)"
    );
    log.note(&format!("coded decode tok/s {window}"), coded_tok_s);
    log.note(&format!("dequant decode tok/s {window}"), dequant_tok_s);
    log.note(&format!("speedup coded decode {window}"), coded_speedup);
    drop(pw_dequant);
    drop(pw_coded);
    drop(cbase);

    // ---- end-to-end: quantize the synthetic tiny model, serve it,
    // drive it with concurrent clients
    let (cfg, teacher, corpus) = synthetic_tiny_setup();
    let opts = synthetic_tiny_opts(3.0);
    let qm = quantize_model(&cfg, &teacher, &corpus, &opts, None)?;
    let container = Container::new(&cfg.name, qm.quants.clone());
    println!(
        "quantized synthetic tiny model: {:.1} KiB container",
        container.size_bytes() as f64 / 1024.0
    );
    let server = Server::from_container(
        &cfg,
        &teacher,
        &container,
        prec,
        ServeOpts::default(),
    )?;
    let clients = env_usize("WATERSIC_SERVE_CLIENTS", 8);
    let per_client = env_usize("WATERSIC_SERVE_REQUESTS", 8);
    let rep = load_test(&server, clients, per_client, 99, &LoadMix::default())?;
    rep.print();
    log.meta("serve clients", Json::Num(clients as f64));
    log.meta("serve batch max", Json::Num(serve_batch_from_env() as f64));
    log.note("serve throughput tok/s", rep.throughput_tok_s);
    log.note("serve p50 ms", rep.p50_ms);
    log.note("serve p90 ms", rep.p90_ms);
    log.note("serve p99 ms", rep.p99_ms);
    log.note("serve mean batch", rep.mean_batch);
    log.note("serve max batch", rep.max_batch as f64);

    // generate-heavy mix: half the requests are greedy generations
    // with heavy-tailed lengths — the workload where TTFT and
    // inter-token latency (not whole-request p99) are the story
    let gen_mix = LoadMix {
        generate_frac: 0.5,
        heavy_tail: true,
        max_steps: 32,
    };
    let rep_gen = load_test(&server, clients, per_client, 100, &gen_mix)?;
    rep_gen.print();
    log.note("serve gen tok/s", rep_gen.gen_tok_s);
    log.note("serve ttft p50 ms", rep_gen.ttft_p50_ms);
    log.note("serve ttft p99 ms", rep_gen.ttft_p99_ms);
    log.note("serve itl p50 ms", rep_gen.itl_p50_ms);
    log.note("serve itl p99 ms", rep_gen.itl_p99_ms);
    log.note("serve decode steps", rep_gen.decode_steps as f64);

    let stats = server.shutdown();
    println!(
        "served {} requests in {} batches ({} tokens, {} decode steps)",
        stats.requests, stats.batches, stats.tokens, stats.decode_steps
    );

    // ---- overload: open-loop arrivals at 2× measured capacity into a
    // small bounded queue.  The probe offers far beyond any plausible
    // service rate, so its accepted/wall IS the drain rate; the 2× run
    // must then shed at admission while accepted-request latency stays
    // bounded by the queue, not by the arrival backlog.
    let osrv = Server::from_container(
        &cfg,
        &teacher,
        &container,
        prec,
        ServeOpts {
            queue_max: 16,
            ..ServeOpts::default()
        },
    )?;
    let probe = load_test_open(&osrv, 200_000.0, Duration::from_millis(400), 101)?;
    let cap_rps = (probe.accepted as f64 / probe.wall_secs.max(1e-9)).max(50.0);
    println!("measured serve capacity: {cap_rps:.0} req/s");
    let rep_unc = load_test_open(
        &osrv,
        (cap_rps * 0.25).max(25.0),
        Duration::from_millis(800),
        102,
    )?;
    rep_unc.print();
    let rep_over = load_test_open(
        &osrv,
        cap_rps * 2.0,
        Duration::from_millis(800),
        103,
    )?;
    rep_over.print();
    let ostats = osrv.shutdown();
    println!(
        "overload server: {} requests in {} batches ({} shed)",
        ostats.requests, ostats.batches, ostats.shed
    );
    log.note("serve capacity rps", cap_rps);
    log.note("p99 uncontended ms", rep_unc.p99_ms);
    log.note("shed frac 2x", rep_over.shed_frac);
    log.note("p99 under overload ms", rep_over.p99_ms);

    match log.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write bench log: {e}"),
    }

    // opt-in hard gates (see module docs)
    if watersic::util::env::flag("WATERSIC_BENCH_ENFORCE") {
        println!("enforcing entries: {}", GATED_ENTRIES.join(", "));
        let (shape, min) = ("16x512x512", 1.05);
        let got = prepack_speedups
            .iter()
            .find(|(n, _)| n == shape)
            .map(|(_, s)| *s)
            .unwrap_or(0.0);
        if got < min {
            eprintln!("GATE FAILED: prepack {shape} speedup {got:.2}× < {min}×");
            std::process::exit(1);
        }
        println!("gate ok: prepack {shape} {got:.2}× ≥ {min}×");
        let min_decode = 10.0;
        if decode_speedup < min_decode {
            eprintln!(
                "GATE FAILED: decode speedup {decode_speedup:.1}× < {min_decode}× at window {window}"
            );
            std::process::exit(1);
        }
        println!("gate ok: decode {decode_speedup:.1}× ≥ {min_decode}× at window {window}");
        // overload: accepted work must finish cleanly, admission must
        // actually shed at 2× capacity, and the bounded queue must keep
        // accepted p99 within a fixed multiple of the uncontended p99
        if rep_over.errors > 0 || rep_unc.errors > 0 {
            eprintln!(
                "GATE FAILED: {} errors under overload ({} uncontended)",
                rep_over.errors, rep_unc.errors
            );
            std::process::exit(1);
        }
        if rep_over.shed == 0 {
            eprintln!("GATE FAILED: no sheds at 2× capacity — admission control inert");
            std::process::exit(1);
        }
        let p99_cap = (rep_unc.p99_ms * 25.0).max(25.0);
        if rep_over.p99_ms > p99_cap {
            eprintln!(
                "GATE FAILED: overload p99 {:.2} ms > {:.2} ms (25× uncontended {:.2} ms)",
                rep_over.p99_ms, p99_cap, rep_unc.p99_ms
            );
            std::process::exit(1);
        }
        println!(
            "gate ok: overload shed {:.0}%, accepted p99 {:.2} ms ≤ {:.2} ms",
            rep_over.shed_frac * 100.0,
            rep_over.p99_ms,
            p99_cap
        );
        // coded residency: the bit-packed panel codes plus decode side
        // info must stay near the entropy-coded artifact's code plane,
        // and serving straight from codes must not lose decode
        // throughput against the eager panels it replaces
        let max_resident = artifact_code_bytes as f64 * 1.25;
        if coded_resident as f64 > max_resident {
            eprintln!(
                "GATE FAILED: coded bytes resident {coded_resident} > 1.25× artifact code bytes {artifact_code_bytes}"
            );
            std::process::exit(1);
        }
        println!(
            "gate ok: coded resident {:.2} MiB ≤ 1.25× artifact code plane {:.2} MiB",
            coded_resident as f64 / (1024.0 * 1024.0),
            artifact_code_bytes as f64 / (1024.0 * 1024.0)
        );
        let min_coded = 1.0;
        if coded_speedup < min_coded {
            eprintln!(
                "GATE FAILED: coded decode speedup {coded_speedup:.2}× < {min_coded}× at window {window}"
            );
            std::process::exit(1);
        }
        println!("gate ok: coded decode {coded_speedup:.2}× ≥ {min_coded}× at window {window}");
    }
    Ok(())
}
