//! PJRT runtime latency: compiled-artifact execution (ZSIC + forward)
//! vs the native oracle — the production request path.

use std::time::Duration;

use watersic::experiments::Ctx;
use watersic::linalg::chol::cholesky;
use watersic::linalg::gemm::matmul;
use watersic::linalg::Mat;
use watersic::model::transformer::{forward, ForwardOpts};
use watersic::quant::waterfilling::ar1_sigma;
use watersic::quant::zsic::{watersic_alphas, zsic};
use watersic::runtime::ZsicArtifact;
use watersic::util::bench::{report, Bench};
use watersic::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("== bench_runtime: PJRT artifacts vs native oracle ==");
    let ctx = Ctx::new(true, true)?;
    let Some(engine) = &ctx.engine else {
        println!("skipped: PJRT engine unavailable");
        return Ok(());
    };
    let mut rng = Rng::new(4);

    for (a, n) in [(512usize, 128usize), (1024, 256)] {
        let sigma = ar1_sigma(n, 0.9);
        let l = cholesky(&sigma)?;
        let w = Mat::from_fn(a, n, |_, _| rng.gaussian());
        let y = matmul(&w, &l);
        let alphas = watersic_alphas(&l, 0.3);
        let art = ZsicArtifact { a, n, lmmse: true };
        // warm the executable cache (compile once)
        engine.run_zsic(art, &y, &l, &alphas)?;
        let s = Bench::new(&format!("zsic {a}x{n} pjrt"))
            .with_budget(5, Duration::from_secs(3))
            .run(|| {
                std::hint::black_box(engine.run_zsic(art, &y, &l, &alphas).unwrap());
            });
        report(&s, Some(((a * n) as f64, "weights")));
        let s = Bench::new(&format!("zsic {a}x{n} native"))
            .with_budget(5, Duration::from_secs(3))
            .run(|| {
                std::hint::black_box(zsic(&y, &l, &alphas, true, None));
            });
        report(&s, Some(((a * n) as f64, "weights")));
    }

    if let Ok((cfg, weights)) = ctx.load_model("picollama_s") {
        let corpus = ctx.load_corpus("wiki")?;
        let windows = corpus.eval_windows(8, cfg.ctx, 5);
        let mut toks = Vec::new();
        for (i, _) in &windows {
            toks.extend_from_slice(i);
        }
        engine.run_forward(&cfg, &weights, &toks, 8)?; // warm compile
        let tokens = (8 * cfg.ctx) as f64;
        let s = Bench::new("forward s b8 pjrt")
            .with_budget(5, Duration::from_secs(3))
            .run(|| {
                std::hint::black_box(
                    engine.run_forward(&cfg, &weights, &toks, 8).unwrap(),
                );
            });
        report(&s, Some((tokens, "tok")));
        let s = Bench::new("forward s b8 native")
            .with_budget(5, Duration::from_secs(3))
            .run(|| {
                std::hint::black_box(forward(
                    &cfg,
                    &weights,
                    &toks,
                    8,
                    cfg.ctx,
                    &ForwardOpts::default(),
                ));
            });
        report(&s, Some((tokens, "tok")));
    }
    Ok(())
}
