"""Layer-2 JAX model: `picollama`, a byte-level pre-LN transformer LM.

Architecturally a scaled-down Llama (RMSNorm, RoPE, causal multi-head
attention, SiLU-gated FFN, residual stream) so that every code path the
paper exercises exists here: down-projections (w_o, w_2) feeding the
residual stream, jointly-quantized QKV projections, RMSNorm-induced dead
features, and softmax error amplification.

The forward pass routes every quantizable linear layer through the
Layer-1 Pallas matmul kernel when ``use_pallas=True`` (the configuration
that gets AOT-lowered to HLO for the Rust runtime).  Training uses the
plain-jnp path for speed; numerics of the two paths are asserted equal
in the pytest suite.

Weight naming convention (shared verbatim with the Rust side):
  embed                     (V, D)
  layers.{i}.norm1          (D,)
  layers.{i}.attn.wq|wk|wv|wo   (D, D)    stored (out, in)
  layers.{i}.norm2          (D,)
  layers.{i}.ffn.w1|w3      (F, D)
  layers.{i}.ffn.w2         (D, F)
  final_norm                (D,)
  head                      (V, D)
The 7 per-block matrices are the quantization targets, matching the
paper's layerwise pipeline.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import matmul as mm
from .kernels import zsic as zsic_kernel


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 256
    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 256
    ctx: int = 128
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_shapes(self) -> Dict[str, tuple]:
        shapes = {"embed": (self.vocab, self.d_model)}
        for i in range(self.n_layers):
            p = f"layers.{i}."
            shapes[p + "norm1"] = (self.d_model,)
            for w in ("wq", "wk", "wv", "wo"):
                shapes[p + f"attn.{w}"] = (self.d_model, self.d_model)
            shapes[p + "norm2"] = (self.d_model,)
            shapes[p + "ffn.w1"] = (self.d_ff, self.d_model)
            shapes[p + "ffn.w3"] = (self.d_ff, self.d_model)
            shapes[p + "ffn.w2"] = (self.d_model, self.d_ff)
        shapes["final_norm"] = (self.d_model,)
        shapes["head"] = (self.vocab, self.d_model)
        return shapes

    def n_params(self) -> int:
        return sum(int(np.prod(s)) for s in self.param_shapes().values())

    def quantizable(self):
        """Names of the per-block linear layers the paper quantizes."""
        out = []
        for i in range(self.n_layers):
            p = f"layers.{i}."
            out += [p + f"attn.{w}" for w in ("wq", "wk", "wv", "wo")]
            out += [p + f"ffn.{w}" for w in ("w1", "w3", "w2")]
        return out


# Two model sizes stand in for the paper's Llama-3.2-1B / Qwen3-8B pair.
PICOLLAMA_S = ModelConfig(name="picollama_s", d_model=64, n_heads=4,
                          n_layers=2, d_ff=256)
PICOLLAMA_M = ModelConfig(name="picollama_m", d_model=128, n_heads=4,
                          n_layers=2, d_ff=512)
CONFIGS = {c.name: c for c in (PICOLLAMA_S, PICOLLAMA_M)}


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in cfg.param_shapes().items():
        key, sub = jax.random.split(key)
        if name.endswith(("norm1", "norm2", "final_norm")):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-1]
            params[name] = (jax.random.normal(sub, shape, jnp.float32)
                            / jnp.sqrt(fan_in))
    return params


def rms_norm(x: jax.Array, gain: jax.Array, eps: float) -> jax.Array:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gain


def _rope_tables(ctx: int, head_dim: int, theta: float):
    pos = jnp.arange(ctx, dtype=jnp.float32)[:, None]
    idx = jnp.arange(head_dim // 2, dtype=jnp.float32)[None, :]
    freqs = pos / (theta ** (2.0 * idx / head_dim))
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, H, T, hd) with hd split into two half-planes."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def _linear(x, w, use_pallas: bool):
    if use_pallas:
        return mm.linear(x, w)
    return x @ w.T


def forward(params: Dict[str, jax.Array], tokens: jax.Array,
            cfg: ModelConfig, *, use_pallas: bool = False,
            collect_attn: bool = False):
    """Run the LM; tokens (B, T) int32 → logits (B, T, V).

    With collect_attn=True also returns the per-layer attention
    probability tensors (B, H, T, T) — used to validate the Rust-side
    attention-weighted calibration (eq. 19) against the same numbers.
    """
    B, T = tokens.shape
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    x = params["embed"][tokens]          # (B, T, D)
    cos, sin = _rope_tables(T, hd, cfg.rope_theta)
    mask = jnp.tril(jnp.ones((T, T), jnp.float32))
    neg = jnp.float32(-1e9)
    attns = []
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        h = rms_norm(x, params[p + "norm1"], cfg.norm_eps)
        q = _linear(h, params[p + "attn.wq"], use_pallas)
        k = _linear(h, params[p + "attn.wk"], use_pallas)
        v = _linear(h, params[p + "attn.wv"], use_pallas)
        q = apply_rope(q.reshape(B, T, H, hd).transpose(0, 2, 1, 3), cos, sin)
        k = apply_rope(k.reshape(B, T, H, hd).transpose(0, 2, 1, 3), cos, sin)
        v = v.reshape(B, T, H, hd).transpose(0, 2, 1, 3)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
        scores = jnp.where(mask[None, None] > 0, scores, neg)
        probs = jax.nn.softmax(scores, axis=-1)
        if collect_attn:
            attns.append(probs)
        ctxv = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
        ctxv = ctxv.transpose(0, 2, 1, 3).reshape(B, T, D)
        x = x + _linear(ctxv, params[p + "attn.wo"], use_pallas)
        h = rms_norm(x, params[p + "norm2"], cfg.norm_eps)
        gate = jax.nn.silu(_linear(h, params[p + "ffn.w1"], use_pallas))
        up = _linear(h, params[p + "ffn.w3"], use_pallas)
        x = x + _linear(gate * up, params[p + "ffn.w2"], use_pallas)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _linear(x, params["head"], use_pallas)
    if collect_attn:
        return logits, attns
    return logits


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean next-token CE in nats; logits (B, T, V), targets (B, T)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return -jnp.mean(picked)


def quantize_graph(y: jax.Array, l: jax.Array, alphas: jax.Array, *,
                   lmmse: bool = True):
    """L2 wrapper of the L1 ZSIC kernel — the graph AOT-exported per
    layer shape.  Inputs are the fully L3-prepared quantities (damped /
    drift-corrected ŷ and L̂, spacing vector); outputs the integer codes,
    LMMSE shrinkages, and residual panel."""
    return zsic_kernel.zsic(y, l, alphas, lmmse=lmmse)


def param_order(cfg: ModelConfig):
    """Flattened parameter order used by the exported forward HLO.

    jax.jit flattens dict params in sorted-key order; the Rust runtime
    relies on this exact list (also recorded in the artifact manifest).
    """
    return sorted(cfg.param_shapes().keys())
