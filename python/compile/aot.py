"""AOT entry point: python runs ONCE here, never on the request path.

`make artifacts` invokes this module to produce everything the Rust
binary needs, into ``artifacts/``:

  corpus_wiki.txt / corpus_web.txt      synthetic corpora (data.py)
  models/<name>/<param>.npy + meta.json trained picollama weights
  forward_<name>.hlo.txt                batched scoring forward pass
                                        (Pallas matmul path) as HLO TEXT
  zsic_{plain,lmmse}_<a>x<n>.hlo.txt    L2 quantize graph per layer shape
  manifest.json                         shapes, parameter order, rates

HLO *text* (never ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` crate binds) rejects; the text
parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data
from . import model as M
from . import train as T

CORPUS_BYTES = 400_000
TRAIN = {
    "picollama_s": dict(steps=350, batch=16),
    "picollama_m": dict(steps=300, batch=8),
}
# Scoring batch used by the exported forward pass (Rust feeds windows of
# exactly this shape; the eval harness tiles/pads to it).
EVAL_BATCH = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _write(path: str, text: str):
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] wrote {path} ({len(text)} bytes)", flush=True)


def export_forward(cfg: M.ModelConfig, out_dir: str):
    """Lower the Pallas-path forward pass with weights as parameters.

    Weights-as-parameters means Rust can feed *quantized* weights without
    recompiling — the whole point of the artifact.
    """
    shapes = cfg.param_shapes()
    params_spec = {k: jax.ShapeDtypeStruct(v, jnp.float32)
                   for k, v in shapes.items()}
    tok_spec = jax.ShapeDtypeStruct((EVAL_BATCH, cfg.ctx), jnp.int32)
    fn = lambda p, t: (M.forward(p, t, cfg, use_pallas=True),)
    lowered = jax.jit(fn).lower(params_spec, tok_spec)
    _write(os.path.join(out_dir, f"forward_{cfg.name}.hlo.txt"),
           to_hlo_text(lowered))


def export_zsic(a: int, n: int, lmmse: bool, out_dir: str):
    y = jax.ShapeDtypeStruct((a, n), jnp.float32)
    l = jax.ShapeDtypeStruct((n, n), jnp.float32)
    al = jax.ShapeDtypeStruct((n,), jnp.float32)
    fn = lambda y_, l_, a_: tuple(M.quantize_graph(y_, l_, a_, lmmse=lmmse))
    lowered = jax.jit(fn).lower(y, l, al)
    tag = "lmmse" if lmmse else "plain"
    _write(os.path.join(out_dir, f"zsic_{tag}_{a}x{n}.hlo.txt"),
           to_hlo_text(lowered))


def zsic_shapes(cfg: M.ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    return [(d, d), (f, d), (d, f)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    os.makedirs(os.path.join(out, "models"), exist_ok=True)
    t0 = time.time()

    # 1. corpora ---------------------------------------------------------
    corpora = {}
    for domain, seed in (("wiki", 11), ("web", 29)):
        path = os.path.join(out, f"corpus_{domain}.txt")
        if args.force or not os.path.exists(path):
            blob = data.generate_corpus(domain, CORPUS_BYTES, seed)
            with open(path, "wb") as f:
                f.write(blob)
            print(f"[aot] wrote {path} ({len(blob)} bytes)", flush=True)
        with open(path, "rb") as f:
            corpora[domain] = f.read()

    # 2. train models ----------------------------------------------------
    manifest_models = {}
    for name, cfg in M.CONFIGS.items():
        mdir = os.path.join(out, "models", name)
        meta_path = os.path.join(mdir, "meta.json")
        if args.force or not os.path.exists(meta_path):
            os.makedirs(mdir, exist_ok=True)
            print(f"[aot] training {name} "
                  f"({cfg.n_params()/1e3:.0f}k params)…", flush=True)
            params = T.train(cfg, corpora["wiki"], **TRAIN[name])
            for k, v in params.items():
                np.save(os.path.join(mdir, k.replace("/", "_") + ".npy"),
                        v.astype(np.float32))
            ppl_wiki = T.eval_ppl(cfg, params, corpora["wiki"])
            ppl_web = T.eval_ppl(cfg, params, corpora["web"])
            meta = {
                "name": name,
                "config": {
                    "vocab": cfg.vocab, "d_model": cfg.d_model,
                    "n_heads": cfg.n_heads, "n_layers": cfg.n_layers,
                    "d_ff": cfg.d_ff, "ctx": cfg.ctx,
                    "norm_eps": cfg.norm_eps,
                    "rope_theta": cfg.rope_theta,
                },
                "n_params": cfg.n_params(),
                "param_order": M.param_order(cfg),
                "param_shapes": {k: list(v)
                                 for k, v in cfg.param_shapes().items()},
                "quantizable": cfg.quantizable(),
                "bf16_ppl_wiki": ppl_wiki,
                "bf16_ppl_web": ppl_web,
            }
            with open(meta_path, "w") as f:
                json.dump(meta, f, indent=1)
            print(f"[aot] {name}: wiki PPL {ppl_wiki:.3f} "
                  f"web PPL {ppl_web:.3f}", flush=True)
        with open(meta_path) as f:
            manifest_models[name] = json.load(f)

    # 3. HLO artifacts ----------------------------------------------------
    shapes = set()
    for cfg in M.CONFIGS.values():
        shapes.update(zsic_shapes(cfg))
    shapes.add((1024, 256))  # bench shape
    for name, cfg in M.CONFIGS.items():
        path = os.path.join(out, f"forward_{name}.hlo.txt")
        if args.force or not os.path.exists(path):
            export_forward(cfg, out)
    for (a, n) in sorted(shapes):
        for lmmse in (False, True):
            tag = "lmmse" if lmmse else "plain"
            path = os.path.join(out, f"zsic_{tag}_{a}x{n}.hlo.txt")
            if args.force or not os.path.exists(path):
                export_zsic(a, n, lmmse, out)

    # 4. manifest ----------------------------------------------------------
    manifest = {
        "eval_batch": EVAL_BATCH,
        "models": manifest_models,
        "zsic_shapes": sorted([list(s) for s in shapes]),
        "corpora": {d: f"corpus_{d}.txt" for d in ("wiki", "web")},
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
