"""Build-time training of the picollama models on the synthetic corpus.

Runs ONCE inside `make artifacts` (never on the request path).  Plain
Adam with cosine decay, next-byte cross-entropy, windows sampled from
the corpus with a deterministic LCG.  Training uses the jnp matmul path
(the Pallas interpret path is numerically identical but much slower);
the exported inference HLO uses the Pallas path.
"""

from __future__ import annotations

import functools
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .data import Lcg


def sample_batch(corpus: np.ndarray, batch: int, ctx: int,
                 rng: Lcg) -> np.ndarray:
    """(batch, ctx+1) int32 windows; target is input shifted by one."""
    n = len(corpus) - ctx - 1
    idx = np.array([rng.below(n) for _ in range(batch)])
    return np.stack([corpus[i:i + ctx + 1] for i in idx]).astype(np.int32)


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.float32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = jax.tree_util.tree_map(
        lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m_: m_ / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v_: v_ / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + eps),
        params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


def train(cfg: M.ModelConfig, corpus_bytes: bytes, *, steps: int = 300,
          batch: int = 16, peak_lr: float = 3e-3, seed: int = 7,
          log_every: int = 50) -> Dict[str, np.ndarray]:
    """Train and return params as a dict of numpy arrays."""
    corpus = np.frombuffer(corpus_bytes, dtype=np.uint8)
    params = M.init_params(cfg, seed=seed)
    opt = adam_init(params)
    rng = Lcg(seed * 7919 + 13)

    @functools.partial(jax.jit, static_argnums=(3,))
    def step(params, opt, windows, step_idx_static, lr):
        tokens = windows[:, :-1]
        targets = windows[:, 1:]

        def loss_fn(p):
            logits = M.forward(p, tokens, cfg, use_pallas=False)
            return M.cross_entropy(logits, targets)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    t0 = time.time()
    losses = []
    for it in range(steps):
        # cosine decay with short warmup
        warm = min(1.0, (it + 1) / 20.0)
        lr = peak_lr * warm * 0.5 * (1 + np.cos(np.pi * it / steps))
        windows = jnp.asarray(sample_batch(corpus, batch, cfg.ctx, rng))
        params, opt, loss = step(params, opt, windows, 0, jnp.float32(lr))
        losses.append(float(loss))
        if log_every and (it % log_every == 0 or it == steps - 1):
            bpb = losses[-1] / np.log(2.0)
            print(f"[train {cfg.name}] step {it:4d} loss {losses[-1]:.4f} "
                  f"({bpb:.3f} bpb) lr {lr:.2e} "
                  f"elapsed {time.time()-t0:.1f}s", flush=True)
    return {k: np.asarray(v) for k, v in params.items()}


def eval_ppl(cfg: M.ModelConfig, params, corpus_bytes: bytes, *,
             batches: int = 4, batch: int = 8, seed: int = 99) -> float:
    """Teacher-forced perplexity (e^CE) on held-out windows."""
    corpus = np.frombuffer(corpus_bytes, dtype=np.uint8)
    rng = Lcg(seed)
    jparams = {k: jnp.asarray(v) for k, v in params.items()}
    tot, cnt = 0.0, 0
    fwd = jax.jit(lambda p, t: M.forward(p, t, cfg, use_pallas=False))
    for _ in range(batches):
        win = sample_batch(corpus, batch, cfg.ctx, rng)
        logits = fwd(jparams, jnp.asarray(win[:, :-1]))
        ce = M.cross_entropy(logits, jnp.asarray(win[:, 1:]))
        tot += float(ce)
        cnt += 1
    return float(np.exp(tot / cnt))
