"""Synthetic calibration / evaluation corpora.

The paper calibrates on WikiText-2 and evaluates domain transfer against
C4 / RedPajama.  Neither corpus is available offline, so we generate two
*disjoint-domain* synthetic corpora from a small probabilistic grammar:

  * ``wiki`` — encyclopedic register (used for calibration + in-domain eval)
  * ``web``  — conversational register (off-domain eval, Tables 12/15/16)

The generator is fully deterministic given a seed (own LCG, no numpy RNG
state dependence) so `make artifacts` is reproducible.  Word frequencies
are Zipfian, sentences come from templates with agreement and punctuation,
and there are numeric spans — enough structure for a small byte-level LM
to reach a low bits-per-byte, which is what the rate-vs-quality curves
need.
"""

from __future__ import annotations


class Lcg:
    """64-bit linear congruential generator (same constants as MMIX)."""

    MUL = 6364136223846793005
    INC = 1442695040888963407
    MASK = (1 << 64) - 1

    def __init__(self, seed: int):
        self.state = (seed ^ 0x9E3779B97F4A7C15) & self.MASK
        for _ in range(4):
            self._next()

    def _next(self) -> int:
        self.state = (self.state * self.MUL + self.INC) & self.MASK
        return self.state >> 33

    def below(self, n: int) -> int:
        return self._next() % n

    def uniform(self) -> float:
        return self._next() / float(1 << 31)


def _zipf_pick(rng: Lcg, words: list[str]) -> str:
    """Pick from ``words`` with a Zipf(1.0)-ish distribution."""
    n = len(words)
    # inverse-CDF trick: index ~ n^u - 1 concentrates mass at low ranks
    u = rng.uniform()
    idx = int((n + 1) ** u) - 1
    return words[min(max(idx, 0), n - 1)]


_WIKI_NOUNS = [
    "system", "theory", "river", "empire", "protein", "algorithm", "treaty",
    "galaxy", "mineral", "province", "archive", "lattice", "equation",
    "dynasty", "molecule", "survey", "census", "harbor", "plateau", "colony",
    "compiler", "cathedral", "isotope", "manuscript", "parliament",
]
_WIKI_ADJS = [
    "ancient", "linear", "northern", "optimal", "notable", "coastal",
    "federal", "thermal", "discrete", "maritime", "industrial", "classical",
    "adjacent", "abundant", "formal", "stable", "central", "regional",
]
_WIKI_VERBS = [
    "describes", "contains", "produces", "governs", "denotes", "spans",
    "precedes", "yields", "encodes", "borders", "supports", "implies",
    "exhibits", "comprises", "resembles", "determines",
]
_WIKI_TEMPLATES = [
    "The {a} {n} {v} the {a2} {n2}.",
    "In {y}, the {n} of {N} {v} a {a} {n2}.",
    "A {a} {n} is a {n2} that {v} {m} {n3}s.",
    "The {n} was established in {y} and {v} the {n2}.",
    "Each {a} {n} {v} approximately {m} {n2}s per {n3}.",
    "Researchers noted that the {n} {v} a {a} {n2} in {y}.",
    "The {a} {n}, first recorded in {y}, {v} the {a2} {n2}.",
]

_WEB_NOUNS = [
    "recipe", "gadget", "playlist", "weekend", "coupon", "sneaker", "podcast",
    "roadtrip", "browser", "smoothie", "backpack", "meetup", "thread",
    "charger", "sticker", "snack", "puzzle", "garage", "ticket", "banner",
]
_WEB_ADJS = [
    "awesome", "cheap", "quick", "tiny", "crazy", "fresh", "handy", "spicy",
    "cozy", "viral", "glossy", "retro", "noisy", "shiny", "lazy", "zesty",
]
_WEB_VERBS = [
    "loves", "shares", "grabs", "posts", "tries", "ships", "streams",
    "fixes", "rates", "swaps", "bundles", "unboxes", "reviews", "tweaks",
]
_WEB_TEMPLATES = [
    "Honestly, this {a} {n} {v} my {a2} {n2}!",
    "Top {m} reasons your {n} {v} a {a} {n2}.",
    "I just {v2} a {a} {n} and it {v} the {n2}.",
    "Who else {v} {a} {n}s on a {n2}?",
    "Deal alert: {a} {n} for only {m} credits.",
    "My {n} {v} the {a} {n2} every single {n3}.",
]

_NAMES = ["Aldren", "Borvia", "Cethia", "Doral", "Evaria", "Fenwick",
          "Garona", "Helmast", "Ivoria", "Jurath"]


def _fill(rng: Lcg, template: str, nouns, adjs, verbs) -> str:
    out = template
    repl = {
        "{a}": lambda: _zipf_pick(rng, adjs),
        "{a2}": lambda: _zipf_pick(rng, adjs),
        "{n}": lambda: _zipf_pick(rng, nouns),
        "{n2}": lambda: _zipf_pick(rng, nouns),
        "{n3}": lambda: _zipf_pick(rng, nouns),
        "{v}": lambda: _zipf_pick(rng, verbs),
        "{v2}": lambda: _zipf_pick(rng, verbs),
        "{N}": lambda: _NAMES[rng.below(len(_NAMES))],
        "{y}": lambda: str(1400 + rng.below(620)),
        "{m}": lambda: str(2 + rng.below(97)),
    }
    for key, fn in repl.items():
        while key in out:
            out = out.replace(key, fn(), 1)
    return out


def generate_corpus(domain: str, n_bytes: int, seed: int) -> bytes:
    """Generate roughly ``n_bytes`` of text for ``domain`` in {wiki, web}."""
    if domain == "wiki":
        nouns, adjs, verbs, templates = (
            _WIKI_NOUNS, _WIKI_ADJS, _WIKI_VERBS, _WIKI_TEMPLATES)
    elif domain == "web":
        nouns, adjs, verbs, templates = (
            _WEB_NOUNS, _WEB_ADJS, _WEB_VERBS, _WEB_TEMPLATES)
    else:
        raise ValueError(f"unknown domain {domain!r}")

    rng = Lcg(seed)
    chunks: list[str] = []
    total = 0
    para_len = 0
    while total < n_bytes:
        sent = _fill(rng, templates[rng.below(len(templates))],
                     nouns, adjs, verbs)
        sep = " "
        para_len += 1
        if para_len >= 4 + rng.below(5):
            sep = "\n"
            para_len = 0
        chunks.append(sent + sep)
        total += len(sent) + 1
    return "".join(chunks).encode("utf-8")[:n_bytes]
