"""Layer-1 Pallas kernel: tiled matmul for the picollama forward pass.

Every quantizable linear layer in the exported forward graph routes its
activations through this kernel (x @ Wᵀ), so the AOT HLO exercises the
Pallas lowering path end to end.  Blocking follows the standard MXU
pattern: (BM × K) · (K × BN) tiles with the full contraction dimension
resident (layer widths here are ≤ 512, so a K-resident schedule fits
VMEM comfortably; see vmem_bytes).

interpret=True is mandatory on CPU PJRT (see zsic.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BN = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                         preferred_element_type=jnp.float32)


def matmul(x: jax.Array, w: jax.Array, *, bm: int = DEFAULT_BM,
           bn: int = DEFAULT_BN, interpret: bool = True) -> jax.Array:
    """Compute x @ w with a tiled Pallas kernel.

    x: (m, k) float32;  w: (k, n) float32  →  (m, n) float32.
    Tile sizes are clamped to the problem size; m and n must be divisible
    by the (clamped) tiles.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = min(bm, m)
    bn = min(bn, n)
    if m % bm or n % bn:
        raise ValueError(f"({m},{n}) not divisible by tiles ({bm},{bn})")

    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), w.astype(jnp.float32))


def linear(x: jax.Array, w: jax.Array, *, interpret: bool = True):
    """Row-major linear layer: x (…, in) · Wᵀ with W stored (out, in)."""
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    y = matmul(x2, w.T, interpret=interpret)
    return y.reshape((*lead, w.shape[0]))


def vmem_bytes(m: int, n: int, k: int, bm: int = DEFAULT_BM,
               bn: int = DEFAULT_BN) -> int:
    """Static VMEM estimate: one x tile + one w tile + one out tile."""
    bm = min(bm, m)
    bn = min(bn, n)
    return 4 * (bm * k + k * bn + bm * bn)
