"""Pure-numpy oracles for the Pallas kernels.

These are the CORE correctness references: deliberately written as the
most literal possible transcription of Algorithm 1 (ZSIC) and the LMMSE
correction of Section 4, with an explicit python loop over columns.  The
Pallas kernels, the Rust-native implementation, and the PJRT artifacts
are all validated against these functions.
"""

from __future__ import annotations

import numpy as np


def round_ties_even(x: np.ndarray) -> np.ndarray:
    """numpy's np.round is already round-half-to-even (banker's rounding).

    Exposed under an explicit name because the Rust side must use
    f32::round_ties_even to match bit-for-bit on .5 ties.
    """
    return np.round(x)


def ref_zsic(y: np.ndarray, l: np.ndarray, alphas: np.ndarray,
             lmmse: bool = True):
    """Algorithm 1 (ZSIC) with the optional LMMSE correction of Section 4.

    Args:
      y: (a, n) input Y = W L (or the drift-corrected y-hat).
      l: (n, n) lower-triangular Cholesky factor.
      alphas: (n,) per-column grid spacings (diagonal of A).
      lmmse: apply the per-column shrinkage gamma_i of eq. (15).

    Returns:
      z: (a, n) int32 integer codes.
      gammas: (n,) LMMSE shrinkage factors (all-ones when lmmse=False).
      resid: (a, n) final residual panel; column i equals
             Y_{:,i} - gamma_i alpha_i l_ii z_i after all interference
             updates, i.e. the per-column quantization error e_SIC
             (Lemma 3.2: without LMMSE it lies in CUBE . A diag(L)).
    """
    a, n = y.shape
    assert l.shape == (n, n) and alphas.shape == (n,)
    yw = y.astype(np.float64).copy()
    l = l.astype(np.float64)
    alphas = alphas.astype(np.float64)
    z = np.zeros((a, n), dtype=np.int64)
    gammas = np.ones(n, dtype=np.float64)
    for i in range(n - 1, -1, -1):
        s = alphas[i] * l[i, i]
        col = yw[:, i]
        zi = round_ties_even(col / s)
        z[:, i] = zi.astype(np.int64)
        if lmmse:
            den = s * float(zi @ zi)
            if den > 0.0:
                gammas[i] = float(col @ zi) / den
        # Full-width interference update; columns > i see L[i, j>i] == 0,
        # column i itself becomes the residual error (never read again).
        yw -= (gammas[i] * alphas[i]) * np.outer(zi, l[i, :])
    return (z.astype(np.int32), gammas.astype(np.float32),
            yw.astype(np.float32))


def ref_dequant(z: np.ndarray, alphas: np.ndarray,
                gammas=None) -> np.ndarray:
    """W-hat = Z . diag(gamma_i alpha_i)  (Section 4, LMMSE correction)."""
    scale = alphas if gammas is None else alphas * gammas
    return z.astype(np.float32) * scale[None, :].astype(np.float32)


def ref_layer_distortion(w: np.ndarray, w_hat: np.ndarray,
                         sigma: np.ndarray) -> float:
    """D = tr((W-What) Sigma (W-What)^T) / (n*a)   (eq. 1)."""
    d = (w - w_hat).astype(np.float64)
    return float(np.trace(d @ sigma.astype(np.float64) @ d.T)) / d.size


def ref_matmul(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Oracle for the tiled Pallas matmul: x @ w."""
    return (x.astype(np.float64) @ w.astype(np.float64)).astype(np.float32)


def ref_watersic_alphas(l: np.ndarray, c: float) -> np.ndarray:
    """WaterSIC spacing rule (eq. 12): alpha_i = c / l_ii."""
    return (c / np.abs(np.diag(l))).astype(np.float32)


def ref_gptq_alphas(n: int, alpha: float) -> np.ndarray:
    """GPTQ spacing rule: A = alpha I."""
    return np.full(n, alpha, dtype=np.float32)


def ref_entropy_bits(z: np.ndarray) -> float:
    """Empirical Shannon entropy (bits/entry) of an integer matrix."""
    _, counts = np.unique(z, return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum())
