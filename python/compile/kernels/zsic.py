"""Layer-1 Pallas kernel: ZSIC successive-interference-cancellation quantizer.

This is the compute hot-spot of WaterSIC (Algorithm 1 + the LMMSE
correction of Section 4).  The paper's reference implementation is a GPU
(H100) batched rank-1 update; the TPU re-think here (DESIGN.md
§Hardware-Adaptation) is:

  * the (a, n) residual panel Y lives in VMEM for the whole kernel and is
    carried across a *sequential* grid over column blocks (the canonical
    TPU accumulator-revisit pattern) — no HBM round trips per column;
  * columns are processed right-to-left; the per-column interference
    update is expressed as a full-width outer product z · L[i, :], which
    maps onto the MXU.  Columns j > i are untouched because L is lower
    triangular (L[i, j>i] = 0), and column i itself becomes the residual
    error e_SIC — it is never read again, so no masking is needed;
  * rounding + LMMSE shrinkage are VPU element-wise ops.

interpret=True is mandatory: the CPU PJRT client cannot execute Mosaic
custom-calls, and all correctness claims are validated through the
interpret path against kernels/ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Column-block width of the sequential grid.  Power-of-two layer widths
# (64/128/256/512) are all divisible by it or smaller than it.
DEFAULT_BLOCK = 64


def _zsic_kernel(y_ref, l_ref, a_ref, z_ref, g_ref, r_ref, *,
                 blk: int, nb: int, lmmse: bool):
    k = pl.program_id(0)
    j = nb - 1 - k  # process blocks right-to-left

    # First grid step: initialize the resident residual panel from Y.
    @pl.when(k == 0)
    def _init():
        r_ref[...] = y_ref[...]

    base = j * blk

    def body(t, _):
        c = blk - 1 - t          # local column, right-to-left
        i = base + c             # global column index
        col = pl.load(r_ref, (slice(None), pl.dslice(i, 1)))    # (a, 1)
        lrow = pl.load(l_ref, (pl.dslice(i, 1), slice(None)))   # (1, n)
        lii = pl.load(l_ref, (pl.dslice(i, 1), pl.dslice(i, 1)))
        alpha = pl.load(a_ref, (pl.dslice(i, 1),))               # (1,)
        s = alpha[0] * lii[0, 0]
        z = jnp.round(col / s)   # round-half-to-even, matches ref + Rust
        if lmmse:
            num = jnp.sum(col * z)
            den = s * jnp.sum(z * z)
            gamma = jnp.where(den > 0.0, num / den, 1.0)
        else:
            gamma = jnp.float32(1.0)
        pl.store(z_ref, (slice(None), pl.dslice(c, 1)),
                 z.astype(jnp.int32))
        pl.store(g_ref, (pl.dslice(c, 1),), jnp.full((1,), gamma))
        # Interference cancellation: rank-1 MXU update over the full
        # panel width (see module docstring for why no mask is needed).
        r_ref[...] = r_ref[...] - (gamma * alpha[0]) * (z @ lrow)
        return 0

    jax.lax.fori_loop(0, blk, body, 0)


def zsic(y: jax.Array, l: jax.Array, alphas: jax.Array, *,
         lmmse: bool = True, block: int = DEFAULT_BLOCK,
         interpret: bool = True):
    """Quantize Y = W·L onto the lattice Zⁿ·diag(alphas)·L.

    Args:
      y: (a, n) float32 — rows of W·L (or the drift-corrected ŷ).
      l: (n, n) float32 lower-triangular Cholesky factor of Σ.
      alphas: (n,) float32 per-column spacings (WaterSIC: c/ℓ_ii; GPTQ: α).
      lmmse: apply per-column LMMSE shrinkage γ_i (eq. 15).
      block: column-block width of the sequential grid.
      interpret: must stay True on CPU PJRT (Mosaic is TPU-only).

    Returns:
      (z, gammas, resid): int32 codes (a, n), shrinkages (n,), and the
      final residual panel (a, n) whose column i is the quantization
      error e_SIC of column i.
    """
    a, n = y.shape
    blk = min(block, n)
    if n % blk != 0:
        raise ValueError(f"n={n} must be divisible by block={blk}")
    nb = n // blk

    kernel = functools.partial(_zsic_kernel, blk=blk, nb=nb, lmmse=lmmse)
    z, g, r = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((a, n), lambda k: (0, 0)),     # Y (read once)
            pl.BlockSpec((n, n), lambda k: (0, 0)),     # L resident
            pl.BlockSpec((n,), lambda k: (0,)),         # alphas resident
        ],
        out_specs=[
            pl.BlockSpec((a, blk), lambda k: (0, nb - 1 - k)),  # Z block
            pl.BlockSpec((blk,), lambda k: (nb - 1 - k,)),      # gammas
            pl.BlockSpec((a, n), lambda k: (0, 0)),  # residual, revisited
        ],
        out_shape=[
            jax.ShapeDtypeStruct((a, n), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((a, n), jnp.float32),
        ],
        interpret=interpret,
    )(y.astype(jnp.float32), l.astype(jnp.float32),
      alphas.astype(jnp.float32))
    return z, g, r


def vmem_bytes(a: int, n: int, block: int = DEFAULT_BLOCK) -> int:
    """Static VMEM footprint estimate for the TPU schedule (DESIGN §Perf).

    Resident: residual panel (a·n), L (n·n), alphas (n), plus the Z/γ
    output blocks (a·block + block). float32/int32 = 4 bytes each.
    """
    blk = min(block, n)
    return 4 * (a * n + n * n + n + a * blk + blk + a * n)
