"""AOT export smoke tests: HLO text emission, artifact presence after
`make artifacts`, and manifest consistency."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke():
    fn = lambda x, y: (jnp.matmul(x, y) + 1.0,)
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_zsic_graph_lowers_to_hlo():
    y = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    l = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    a = jax.ShapeDtypeStruct((16,), jnp.float32)
    fn = lambda y_, l_, a_: tuple(M.quantize_graph(y_, l_, a_))
    text = aot.to_hlo_text(jax.jit(fn).lower(y, l, a))
    assert "HloModule" in text
    assert "s32[8,16]" in text  # integer codes output


def test_zsic_shapes_cover_all_layer_matrices():
    for cfg in M.CONFIGS.values():
        shapes = set(aot.zsic_shapes(cfg))
        pshapes = cfg.param_shapes()
        for name in cfg.quantizable():
            assert tuple(pshapes[name]) in shapes, name


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first")


@needs_artifacts
def test_manifest_lists_existing_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for name in man["models"]:
        assert os.path.exists(os.path.join(ART, f"forward_{name}.hlo.txt"))
        meta = man["models"][name]
        for pname in meta["param_order"]:
            npy = os.path.join(ART, "models", name,
                               pname.replace("/", "_") + ".npy")
            assert os.path.exists(npy), npy
    for (a, n) in man["zsic_shapes"]:
        for tag in ("plain", "lmmse"):
            assert os.path.exists(
                os.path.join(ART, f"zsic_{tag}_{a}x{n}.hlo.txt"))


@needs_artifacts
def test_trained_model_beats_uniform():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for name, meta in man["models"].items():
        assert meta["bf16_ppl_wiki"] < 32.0, (
            f"{name} undertrained: ppl {meta['bf16_ppl_wiki']}")
