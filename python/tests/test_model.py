"""L2 model tests: shapes, pallas-vs-jnp path equivalence, training
step sanity, quantize graph round-trip, corpus generator determinism."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import data
from compile import model as M
from compile import train as T

CFG = M.ModelConfig(name="tiny_test", d_model=32, n_heads=2, n_layers=1,
                    d_ff=64, ctx=16)


def _params(cfg=CFG, seed=1):
    return M.init_params(cfg, seed=seed)


def _tokens(cfg=CFG, b=2, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, size=(b, cfg.ctx),
                                    dtype=np.int32))


def test_forward_shapes():
    params = _params()
    logits = M.forward(params, _tokens(), CFG)
    assert logits.shape == (2, CFG.ctx, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_pallas_path_matches_jnp():
    params = _params()
    toks = _tokens()
    a = M.forward(params, toks, CFG, use_pallas=False)
    b = M.forward(params, toks, CFG, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_collect_attn():
    params = _params()
    logits, attns = M.forward(params, _tokens(), CFG, collect_attn=True)
    assert len(attns) == CFG.n_layers
    p = np.asarray(attns[0])
    assert p.shape == (2, CFG.n_heads, CFG.ctx, CFG.ctx)
    # rows sum to 1, causal
    np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-5)
    assert np.allclose(np.triu(p[0, 0], k=1), 0.0, atol=1e-6)


def test_param_order_is_sorted_and_complete():
    order = M.param_order(CFG)
    assert order == sorted(order)
    assert set(order) == set(CFG.param_shapes().keys())


def test_quantizable_list():
    q = CFG.quantizable()
    assert len(q) == 7 * CFG.n_layers
    shapes = CFG.param_shapes()
    for name in q:
        assert len(shapes[name]) == 2


def test_training_reduces_loss():
    corpus = data.generate_corpus("wiki", 30_000, 3)
    params = T.train(CFG, corpus, steps=40, batch=8, log_every=0)
    ppl0 = 256.0  # uniform byte model
    ppl = T.eval_ppl(CFG, params, corpus, batches=2, batch=4)
    # 40 steps on a 1-layer model: expect a clear (not huge) gain
    assert ppl < ppl0 * 0.6, f"training ineffective: ppl={ppl}"


def test_cross_entropy_uniform():
    logits = jnp.zeros((1, 4, 256))
    targets = jnp.zeros((1, 4), jnp.int32)
    ce = float(M.cross_entropy(logits, targets))
    assert abs(ce - np.log(256.0)) < 1e-5


def test_quantize_graph_roundtrip():
    rng = np.random.default_rng(0)
    n, a = 32, 16
    w = rng.normal(size=(a, n)).astype(np.float32)
    q = rng.normal(size=(n, n))
    sigma = (q @ q.T / n + 0.1 * np.eye(n)).astype(np.float32)
    l = np.linalg.cholesky(sigma).astype(np.float32)
    y = w @ l
    alphas = (0.2 / np.abs(np.diag(l))).astype(np.float32)
    z, g, r = M.quantize_graph(jnp.asarray(y), jnp.asarray(l),
                               jnp.asarray(alphas))
    w_hat = np.asarray(z) * (np.asarray(g) * alphas)[None, :]
    d = np.trace((w - w_hat) @ sigma @ (w - w_hat).T) / w.size
    d_rtn = np.trace((w - np.round(w / 0.2) * 0.2) @ sigma
                     @ (w - np.round(w / 0.2) * 0.2).T) / w.size
    assert d < d_rtn, "ZSIC must beat plain RTN at equal lattice density"


def test_corpus_deterministic_and_disjoint():
    a = data.generate_corpus("wiki", 10_000, 11)
    b = data.generate_corpus("wiki", 10_000, 11)
    c = data.generate_corpus("web", 10_000, 29)
    assert a == b
    assert a[:2000] != c[:2000]
    assert len(a) == 10_000


def test_corpus_byte_range():
    blob = data.generate_corpus("web", 5_000, 1)
    arr = np.frombuffer(blob, dtype=np.uint8)
    assert arr.max() < 128  # pure ASCII → byte-level LM vocab is enough


@pytest.mark.parametrize("name,cfg", list(M.CONFIGS.items()))
def test_shipping_configs(name, cfg):
    assert cfg.n_params() > 50_000
    assert cfg.d_model % cfg.n_heads == 0
    assert cfg.head_dim % 2 == 0  # RoPE needs even head dim
