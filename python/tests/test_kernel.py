"""Pallas ZSIC kernel vs the pure-numpy oracle — the CORE correctness
signal of the L1 layer, including a hypothesis sweep over shapes, block
sizes, scales, and covariance conditioning."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as MM
from compile.kernels import ref as R
from compile.kernels import zsic as K


def make_problem(a, n, seed, cond=1.0, sigma_w=1.0):
    rng = np.random.default_rng(seed)
    w = (sigma_w * rng.normal(size=(a, n))).astype(np.float32)
    q = rng.normal(size=(n, n)).astype(np.float64)
    sigma = q @ q.T / n + 0.05 * np.eye(n)
    # optionally skew the spectrum to stress conditioning
    if cond != 1.0:
        d = np.diag(np.geomspace(1.0, cond, n))
        sigma = d @ sigma @ d
    l = np.linalg.cholesky(sigma).astype(np.float32)
    y = (w.astype(np.float64) @ l.astype(np.float64)).astype(np.float32)
    return w, sigma.astype(np.float32), l, y


@pytest.mark.parametrize("lmmse", [False, True])
@pytest.mark.parametrize("a,n,block", [(16, 32, 16), (32, 64, 64),
                                       (8, 48, 16), (64, 16, 16)])
def test_zsic_matches_ref(a, n, block, lmmse):
    _, _, l, y = make_problem(a, n, seed=a * 1000 + n)
    alphas = R.ref_watersic_alphas(l, 0.25)
    z, g, r = K.zsic(jnp.asarray(y), jnp.asarray(l), jnp.asarray(alphas),
                     lmmse=lmmse, block=block)
    z0, g0, r0 = R.ref_zsic(y, l, alphas, lmmse=lmmse)
    assert np.array_equal(np.asarray(z), z0)
    np.testing.assert_allclose(np.asarray(g), g0, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(r), r0, rtol=1e-3, atol=1e-4)


def test_zsic_gptq_spacing():
    """A = αI (GPTQ mode) must agree with the oracle too."""
    _, _, l, y = make_problem(24, 32, seed=5)
    alphas = R.ref_gptq_alphas(32, 0.2)
    z, g, r = K.zsic(jnp.asarray(y), jnp.asarray(l), jnp.asarray(alphas),
                     lmmse=False, block=32)
    z0, _, _ = R.ref_zsic(y, l, alphas, lmmse=False)
    assert np.array_equal(np.asarray(z), z0)
    assert np.all(np.asarray(g) == 1.0)


def test_lemma_3_2_error_cube():
    """Lemma 3.2: without LMMSE, e_SIC ∈ CUBE · A diag(L)."""
    _, _, l, y = make_problem(64, 48, seed=9)
    alphas = R.ref_watersic_alphas(l, 0.4)
    _, _, r = K.zsic(jnp.asarray(y), jnp.asarray(l), jnp.asarray(alphas),
                     lmmse=False, block=16)
    bound = 0.5 * alphas * np.abs(np.diag(l)) + 1e-4
    assert np.all(np.abs(np.asarray(r)) <= bound[None, :])


def test_zsic_consistency_z_residual():
    """Y − Z·diag(γα)·L must equal the reported residual panel."""
    _, _, l, y = make_problem(16, 32, seed=3)
    alphas = R.ref_watersic_alphas(l, 0.3)
    z, g, r = K.zsic(jnp.asarray(y), jnp.asarray(l), jnp.asarray(alphas),
                     lmmse=True, block=16)
    recon = (np.asarray(z) * (np.asarray(g) * alphas)[None, :]) @ l
    np.testing.assert_allclose(y - recon, np.asarray(r),
                               rtol=1e-3, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    a=st.integers(4, 40),
    nb=st.integers(1, 4),
    blk=st.sampled_from([8, 16]),
    c=st.floats(0.05, 1.5),
    cond=st.floats(1.0, 50.0),
    lmmse=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_zsic_hypothesis(a, nb, blk, c, cond, lmmse, seed):
    n = nb * blk
    _, _, l, y = make_problem(a, n, seed=seed, cond=cond)
    alphas = R.ref_watersic_alphas(l, c)
    z, g, r = K.zsic(jnp.asarray(y), jnp.asarray(l), jnp.asarray(alphas),
                     lmmse=lmmse, block=blk)
    z0, g0, r0 = R.ref_zsic(y, l, alphas, lmmse=lmmse)
    # Integer codes must match exactly except at knife-edge rounding
    # boundaries introduced by f32-vs-f64 accumulation differences.
    mismatch = (np.asarray(z) != z0).mean()
    assert mismatch < 0.005
    if mismatch == 0:
        np.testing.assert_allclose(np.asarray(g), g0, rtol=5e-4, atol=5e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([8, 16, 32]),
    k=st.sampled_from([8, 24, 64]),
    n=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32)
    out = MM.matmul(jnp.asarray(x), jnp.asarray(w), bm=8, bn=16)
    np.testing.assert_allclose(np.asarray(out), R.ref_matmul(x, w),
                               rtol=1e-4, atol=1e-4)


def test_vmem_budget():
    """Structural perf check: the largest exported shape fits a 16 MiB
    VMEM budget under the documented schedule (DESIGN §Perf)."""
    assert K.vmem_bytes(1024, 256) < 16 * 2**20
    assert K.vmem_bytes(512, 128) < 16 * 2**20
    assert MM.vmem_bytes(1024, 256, 512) < 16 * 2**20
